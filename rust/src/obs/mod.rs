//! End-to-end query tracing (DESIGN.md §Observability).
//!
//! Every served query (and ingest batch) can carry a minted [`TraceId`];
//! instrumented stages record typed [`Span`]s into a per-request
//! [`TraceCtx`] that is **owned by the request** — it moves with the job
//! across threads and is touched without any lock, so instrumentation
//! adds only `Instant` reads to the hot path and cannot perturb
//! selection (no RNG consumption, no float-order changes; the
//! `score_determinism` suite runs with tracing at sample rate 1).
//!
//! Finished span trees are published into the central [`Tracer`]: two
//! bounded rings (all completed traces + the slow-query log) and
//! per-stage latency histograms behind a single [`OrderedMutex`] at rank
//! [`ranks::OBS_TRACER`] — the very top of the lock order, taken only
//! after every other guard is released.  Head-sampling
//! (`[obs] trace_sample_n`) keeps the cost bounded under load, and the
//! disabled path (`trace_sample_n = 0`) allocates nothing and takes no
//! lock.
//!
//! The data is served three ways (see `net::wire`): the `trace` envelope
//! returns span trees by id or recency, `QueryResponse` echoes the trace
//! id so `venus query --trace` can fetch its own breakdown, and the
//! `metrics_text` envelope renders the whole serving [`Snapshot`] plus
//! the span-derived histograms in Prometheus text exposition format.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ObsConfig;
use crate::server::Snapshot;
use crate::util::json::Json;
use crate::util::sync::{ranks, OrderedMutex};

/// Canonical stage names, in pipeline order.  Per-shard scoring spans
/// use the `score/shard` child name (a `/` marks a child of the stage
/// before the slash); everything else is a top-level stage whose
/// durations are disjoint, so their sum approximates the query total.
pub mod stage {
    /// Wire-gateway frame read + decode.  Deliberately a *child* stage
    /// (`/` convention): it happens before the trace is minted, so its
    /// span is appended post-hoc at offset 0 and must not count toward
    /// (or overlap-check against) the top-level stage timeline.
    pub const GATEWAY_READ: &str = "gateway/read";
    pub const QUEUE_WAIT: &str = "queue_wait";
    pub const CACHE_PROBE: &str = "cache_probe";
    /// Semantic (tier-2) probe — runs after the embed, so it is recorded
    /// as a child rather than widening the top-level `cache_probe` span.
    pub const CACHE_PROBE_SEMANTIC: &str = "cache_probe/semantic";
    pub const EMBED: &str = "embed";
    pub const SCORE: &str = "score";
    pub const SCORE_SHARD: &str = "score/shard";
    pub const SELECT: &str = "select";
    pub const FETCH: &str = "fetch";
    pub const UPLOAD: &str = "upload";
    pub const VLM: &str = "vlm";
    /// Wire-gateway reply serialization + socket write; appended after
    /// `finish()`, so a child stage like [`GATEWAY_READ`].
    pub const GATEWAY_WRITE: &str = "gateway/write";
    pub const INGEST_DECODE: &str = "ingest_decode";
    pub const INGEST_PUSH: &str = "ingest_push";

    /// Top-level query stages in pipeline order (for rendering tables).
    pub const QUERY_ORDER: &[&str] = &[
        GATEWAY_READ,
        QUEUE_WAIT,
        CACHE_PROBE,
        EMBED,
        SCORE,
        SELECT,
        FETCH,
        UPLOAD,
        VLM,
        GATEWAY_WRITE,
    ];
}

/// Process-unique trace identifier, rendered as 16 hex digits on the
/// wire and in CLI output.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

impl TraceId {
    /// Parse the 16-hex-digit wire form (also accepts shorter hex).
    pub fn parse(s: &str) -> Option<TraceId> {
        u64::from_str_radix(s.trim(), 16).ok().map(TraceId)
    }
}

/// One timed stage of one request.  `start_us` is the offset from the
/// trace's birth; counters carry stage-specific gauges (rows scored,
/// segments probed/pruned, hot/cold split…) — numbers only, so the wire
/// encoding stays schema-free and tolerant.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub stage: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub counters: BTreeMap<String, f64>,
}

impl Span {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("stage".into(), Json::Str(self.stage.clone()));
        m.insert("start_us".into(), Json::Num(self.start_us as f64));
        m.insert("dur_us".into(), Json::Num(self.dur_us as f64));
        if !self.counters.is_empty() {
            let cm = self
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect::<BTreeMap<_, _>>();
            m.insert("counters".into(), Json::Obj(cm));
        }
        Json::Obj(m)
    }

    /// Tolerant parse: only `stage` is required; offsets, durations and
    /// counters default when absent so old clients read new servers (and
    /// vice versa).
    pub fn from_json(v: &Json) -> Result<Self> {
        let counters = match v.opt("counters") {
            Some(c) => c
                .as_obj()?
                .iter()
                .map(|(k, x)| Ok((k.clone(), x.as_f64()?)))
                .collect::<Result<BTreeMap<_, _>>>()?,
            None => BTreeMap::new(),
        };
        Ok(Span {
            stage: v.get("stage")?.as_str()?.to_string(),
            start_us: v.opt("start_us").map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64,
            dur_us: v.opt("dur_us").map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64,
            counters,
        })
    }

    /// Is this a child span (`score/shard` under `score`)?
    pub fn is_child(&self) -> bool {
        self.stage.contains('/')
    }
}

/// A completed request's span tree, as retained in the tracer rings and
/// served over the `trace` envelope.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub id: TraceId,
    /// `"query"` or `"ingest"`.
    pub kind: String,
    /// Short human label (query text prefix / `stream N`).
    pub label: String,
    /// Wall-clock birth time, unix milliseconds.
    pub unix_ms: u64,
    /// End-to-end duration as reported by the finishing stage.
    pub total_us: u64,
    pub spans: Vec<Span>,
}

impl Trace {
    /// Sum of the top-level stage durations (children excluded — their
    /// time is already inside their parent stage).
    pub fn stage_sum_us(&self) -> u64 {
        self.spans.iter().filter(|s| !s.is_child()).map(|s| s.dur_us).sum()
    }

    pub fn span(&self, stage: &str) -> Option<&Span> {
        self.spans.iter().find(|s| s.stage == stage)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::Str(self.id.to_string()));
        m.insert("kind".into(), Json::Str(self.kind.clone()));
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("unix_ms".into(), Json::Num(self.unix_ms as f64));
        m.insert("total_us".into(), Json::Num(self.total_us as f64));
        m.insert("spans".into(), Json::Arr(self.spans.iter().map(|s| s.to_json()).collect()));
        Json::Obj(m)
    }

    /// Tolerant parse: `id` is required, everything else defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let id = TraceId::parse(v.get("id")?.as_str()?)
            .ok_or_else(|| anyhow::anyhow!("trace id is not hex"))?;
        let spans = match v.opt("spans") {
            Some(arr) => arr.as_arr()?.iter().map(Span::from_json).collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(Trace {
            id,
            kind: v
                .opt("kind")
                .map(|x| x.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "query".into()),
            label: v
                .opt("label")
                .map(|x| x.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
            unix_ms: v.opt("unix_ms").map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64,
            total_us: v.opt("total_us").map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64,
            spans,
        })
    }

    /// Pretty-print the span tree (the `venus query --trace` breakdown):
    /// one line per span, children indented under their parent, with
    /// percentages of the total and the counters inline.
    pub fn render(&self) -> String {
        let total_ms = self.total_us as f64 / 1000.0;
        let mut out = format!(
            "trace {} {} \"{}\" total {:.2}ms ({} spans, stage sum {:.2}ms)\n",
            self.id,
            self.kind,
            self.label,
            total_ms,
            self.spans.len(),
            self.stage_sum_us() as f64 / 1000.0,
        );
        for s in &self.spans {
            let pct = if self.total_us > 0 {
                s.dur_us as f64 * 100.0 / self.total_us as f64
            } else {
                0.0
            };
            let indent = if s.is_child() { "    " } else { "  " };
            let mut line = format!(
                "{indent}{:<14} {:>9.2}ms {:>5.1}%",
                s.stage,
                s.dur_us as f64 / 1000.0,
                pct
            );
            for (k, v) in &s.counters {
                if (v.fract()).abs() < f64::EPSILON {
                    line.push_str(&format!(" {k}={v:.0}"));
                } else {
                    line.push_str(&format!(" {k}={v:.2}"));
                }
            }
            line.push('\n');
            out.push_str(&line);
        }
        out
    }
}

/// Per-request span scratch.  Owned by the job (no lock, no sharing):
/// stages record into it as the request flows through the pipeline, and
/// `Tracer::finish` publishes the result.
#[derive(Debug)]
pub struct TraceCtx {
    id: TraceId,
    kind: &'static str,
    label: String,
    started: Instant,
    unix_ms: u64,
    spans: Vec<Span>,
}

impl TraceCtx {
    pub fn id(&self) -> TraceId {
        self.id
    }

    /// The trace's birth instant — span offsets are measured from here.
    pub fn started(&self) -> Instant {
        self.started
    }

    /// Record a stage that ran from `from` for `dur`.
    pub fn record(&mut self, stage: &str, from: Instant, dur: Duration) {
        self.record_counters(stage, from, dur, &[]);
    }

    /// Record a stage with stage-specific counters attached.
    pub fn record_counters(
        &mut self,
        stage: &str,
        from: Instant,
        dur: Duration,
        counters: &[(&str, f64)],
    ) {
        let start_us = from.saturating_duration_since(self.started).as_micros() as u64;
        self.record_at(stage, start_us, dur.as_micros() as u64, counters);
    }

    /// Record a stage at an explicit microsecond offset.  Used for
    /// *modeled* stages (uplink transfer, cloud VLM inference) whose
    /// simulated latency never elapses on the wall clock: the worker
    /// places them after the measured edge stages so the span tree stays
    /// non-overlapping and its top-level sum still tracks the reported
    /// end-to-end total.
    pub fn record_at(&mut self, stage: &str, start_us: u64, dur_us: u64, counters: &[(&str, f64)]) {
        self.spans.push(Span {
            stage: stage.to_string(),
            start_us,
            dur_us,
            counters: counters.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }
}

/// Microsecond bucket bounds for the per-stage latency histograms
/// (upper-inclusive, Prometheus `le` convention; a 16th +Inf bucket is
/// implicit).  Log-spaced from 100µs to 5s — the serving range between
/// a cache hit and a pathological cold scan.
pub const HIST_BOUNDS_US: [u64; 15] = [
    100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 500_000,
    1_000_000, 2_500_000, 5_000_000,
];

/// One stage's latency histogram (fixed buckets + sum/count).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    /// Per-bucket counts aligned with [`HIST_BOUNDS_US`]; the final
    /// element is the +Inf bucket.
    pub buckets: [u64; 16],
    pub sum_us: u64,
    pub count: u64,
}

impl HistSnapshot {
    fn observe(&mut self, us: u64) {
        let idx = HIST_BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(HIST_BOUNDS_US.len());
        self.buckets[idx] += 1;
        self.sum_us += us;
        self.count += 1;
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

/// Tracer counters surfaced in `venus serve` status output.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ObsCounts {
    /// Traces minted (sampled in).
    pub minted: u64,
    /// Traces finished and published into the completed ring.
    pub finished: u64,
    /// Finished traces that crossed the slow-query bar.
    pub slow: u64,
}

#[derive(Debug, Default)]
struct Rings {
    completed: VecDeque<Trace>,
    slow: VecDeque<Trace>,
    hist: BTreeMap<String, HistSnapshot>,
    finished_total: u64,
    slow_total: u64,
}

/// The central trace collector: sampling decision, bounded rings, and
/// per-stage histograms.  One per serving process, shared by workers,
/// the gateway, and the ingest hub.
#[derive(Debug)]
pub struct Tracer {
    sample_n: usize,
    slow_us: u64,
    trace_ring: usize,
    slow_ring: usize,
    minted: AtomicU64,
    seen: AtomicU64,
    inner: OrderedMutex<Rings>,
}

impl Tracer {
    pub fn new(cfg: &ObsConfig) -> Self {
        Self {
            sample_n: cfg.trace_sample_n,
            slow_us: cfg.slow_query_ms.saturating_mul(1000),
            trace_ring: cfg.trace_ring.max(1),
            slow_ring: cfg.slow_ring.max(1),
            minted: AtomicU64::new(0),
            seen: AtomicU64::new(0),
            inner: OrderedMutex::new(ranks::OBS_TRACER, Rings::default()),
        }
    }

    /// The configured head-sampling rate (0 = disabled).
    pub fn sample_n(&self) -> usize {
        self.sample_n
    }

    /// The slow-query bar in milliseconds (0 = slow log disabled).
    pub fn slow_query_ms(&self) -> u64 {
        self.slow_us / 1000
    }

    /// Head-sampling mint: every `sample_n`-th request gets a ctx; the
    /// rest (and everything when disabled) get `None`.  The disabled
    /// path returns before touching any atomic — zero allocation, zero
    /// contention.
    pub fn mint(&self, kind: &'static str, label: &str) -> Option<TraceCtx> {
        if self.sample_n == 0 {
            return None;
        }
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_n as u64 != 0 {
            return None;
        }
        let id = TraceId(self.minted.fetch_add(1, Ordering::Relaxed) + 1);
        let mut label = label.to_string();
        if label.len() > 80 {
            let cut = (0..=80).rev().find(|&i| label.is_char_boundary(i)).unwrap_or(0);
            label.truncate(cut);
        }
        Some(TraceCtx {
            id,
            kind,
            label,
            started: Instant::now(),
            unix_ms: crate::server::now_unix_ms(),
            spans: Vec::with_capacity(12),
        })
    }

    /// Publish a finished request: push into the completed ring (bounded,
    /// oldest evicted), retain in the slow ring if it crossed the bar,
    /// and fold every top-level span into the per-stage histograms.
    pub fn finish(&self, ctx: TraceCtx, total: Duration) -> TraceId {
        let trace = Trace {
            id: ctx.id,
            kind: ctx.kind.to_string(),
            label: ctx.label,
            unix_ms: ctx.unix_ms,
            total_us: total.as_micros() as u64,
            spans: ctx.spans,
        };
        let id = trace.id;
        let mut r = self.inner.lock();
        r.finished_total += 1;
        for s in trace.spans.iter().filter(|s| !s.is_child()) {
            r.hist.entry(s.stage.clone()).or_default().observe(s.dur_us);
        }
        r.hist.entry("total".into()).or_default().observe(trace.total_us);
        if self.slow_us > 0 && trace.total_us >= self.slow_us {
            r.slow_total += 1;
            if r.slow.len() >= self.slow_ring {
                r.slow.pop_front();
            }
            r.slow.push_back(trace.clone());
        }
        if r.completed.len() >= self.trace_ring {
            r.completed.pop_front();
        }
        r.completed.push_back(trace);
        id
    }

    /// Attach a span to an already-finished trace (the gateway's write
    /// span is only measurable after the response left the socket).
    /// No-op if the trace has already been evicted from both rings.
    pub fn append_span(&self, id: TraceId, span: Span) {
        let mut r = self.inner.lock();
        if !span.is_child() {
            r.hist.entry(span.stage.clone()).or_default().observe(span.dur_us);
        }
        if let Some(t) = r.slow.iter_mut().rev().find(|t| t.id == id) {
            t.spans.push(span.clone());
        }
        if let Some(t) = r.completed.iter_mut().rev().find(|t| t.id == id) {
            t.spans.push(span);
        }
    }

    /// Fetch one trace by id (completed ring first, then the slow ring —
    /// a slow trace outlives its completed-ring copy).
    pub fn lookup(&self, id: TraceId) -> Option<Trace> {
        let r = self.inner.lock();
        r.completed
            .iter()
            .rev()
            .find(|t| t.id == id)
            .or_else(|| r.slow.iter().rev().find(|t| t.id == id))
            .cloned()
    }

    /// The most recent `n` completed traces, newest first.
    pub fn recent(&self, n: usize) -> Vec<Trace> {
        let r = self.inner.lock();
        r.completed.iter().rev().take(n).cloned().collect()
    }

    /// The most recent `n` slow traces, newest first.
    pub fn slow_recent(&self, n: usize) -> Vec<Trace> {
        let r = self.inner.lock();
        r.slow.iter().rev().take(n).cloned().collect()
    }

    pub fn counts(&self) -> ObsCounts {
        let r = self.inner.lock();
        ObsCounts {
            minted: self.minted.load(Ordering::Relaxed),
            finished: r.finished_total,
            slow: r.slow_total,
        }
    }

    /// Per-stage histograms (stage name → snapshot), `total` included.
    pub fn stage_histograms(&self) -> BTreeMap<String, HistSnapshot> {
        self.inner.lock().hist.clone()
    }

    /// One-line summary for `venus serve` status output.
    pub fn render(&self) -> String {
        let c = self.counts();
        format!(
            "obs: 1/{} sampled / {} traced / {} slow (>{}ms)",
            self.sample_n.max(1),
            c.finished,
            c.slow,
            self.slow_query_ms(),
        )
    }
}

fn prom_escape(label: &str) -> String {
    label.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render the serving [`Snapshot`] (plus, when a tracer is present, the
/// span-derived per-stage histograms) in Prometheus text exposition
/// format — the `metrics_text` wire envelope and `venus stats --prom`.
pub fn prometheus_text(snap: &Snapshot, tracer: Option<&Tracer>) -> String {
    let mut out = String::with_capacity(4096);
    let mut gauge = |name: &str, help: &str, v: f64| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
    };
    gauge("venus_uptime_seconds", "Serving process uptime.", snap.uptime_s);
    gauge(
        "venus_started_unix_ms",
        "Wall-clock unix milliseconds the serving process started.",
        snap.started_unix_ms as f64,
    );
    gauge("venus_throughput_qps", "Completed queries per second since start.", snap.throughput_qps);
    gauge("venus_mean_frames_per_query", "Mean evidence frames shipped per query.", snap.mean_frames);
    gauge("venus_queries_failed_total", "Queries that failed in the engine.", snap.failed as f64);
    gauge(
        "venus_queries_shutdown_raced_total",
        "Submissions that raced service shutdown.",
        snap.shutdown as f64,
    );

    out.push_str("# HELP venus_lane_queries_total Per-lane admission counters.\n");
    out.push_str("# TYPE venus_lane_queries_total counter\n");
    out.push_str("# HELP venus_lane_queue_depth Live per-lane queue occupancy.\n");
    out.push_str("# TYPE venus_lane_queue_depth gauge\n");
    for (lane, l) in [("interactive", &snap.interactive), ("batch", &snap.batch)] {
        for (event, v) in [
            ("accepted", l.accepted),
            ("rejected", l.rejected),
            ("completed", l.completed),
            ("deadline_shed", l.deadline_shed),
        ] {
            out.push_str(&format!(
                "venus_lane_queries_total{{lane=\"{lane}\",event=\"{event}\"}} {v}\n"
            ));
        }
        out.push_str(&format!("venus_lane_queue_depth{{lane=\"{lane}\"}} {}\n", l.queued));
    }

    out.push_str("# HELP venus_latency_seconds Serving latency percentiles.\n");
    out.push_str("# TYPE venus_latency_seconds gauge\n");
    for (kind, q, v) in [
        ("queue_wait", "0.5", snap.queue_wait_p50_s),
        ("queue_wait", "0.95", snap.queue_wait_p95_s),
        ("queue_wait", "0.99", snap.queue_wait_p99_s),
        ("edge", "0.5", snap.edge_p50_s),
        ("edge", "0.95", snap.edge_p95_s),
        ("edge", "0.99", snap.edge_p99_s),
        ("total", "0.5", snap.total_p50_s),
        ("total", "0.95", snap.total_p95_s),
        ("total", "0.99", snap.total_p99_s),
    ] {
        if let Some(x) = v {
            out.push_str(&format!(
                "venus_latency_seconds{{kind=\"{kind}\",quantile=\"{q}\"}} {x}\n"
            ));
        }
    }

    if let Some(m) = &snap.memory {
        let mut mg = |name: &str, help: &str, v: f64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        };
        mg("venus_memory_hot_bytes", "Hot-tier resident bytes.", m.hot_bytes as f64);
        mg("venus_memory_hot_records", "Hot-tier index records.", m.hot_records as f64);
        mg("venus_memory_cold_records", "Cold-tier index records.", m.cold_records as f64);
        mg("venus_memory_cold_segments", "Cold-tier sealed segments.", m.cold_segments as f64);
        mg(
            "venus_memory_cold_resident_bytes",
            "Cold-tier block-cache resident bytes.",
            m.cold_resident_bytes as f64,
        );
        mg("venus_memory_evictions_total", "Hot-to-cold segment demotions.", m.evictions as f64);
        mg("venus_memory_cold_hits_total", "Cold block-cache hits.", m.cold_hits as f64);
        mg("venus_memory_cold_misses_total", "Cold block-cache misses.", m.cold_misses as f64);
        mg(
            "venus_memory_cold_probe_segments_total",
            "Cold segments actually scanned (coarse probe survivors).",
            m.cold_probe_segments as f64,
        );
        mg(
            "venus_memory_cold_probe_candidates_total",
            "Cold segments considered by the coarse probe.",
            m.cold_probe_candidates as f64,
        );
        mg("venus_memory_cold_rows_scored_total", "Cold rows scored.", m.cold_rows_scored as f64);
    }

    if let Some(sc) = &snap.scoring {
        let mut sg = |name: &str, help: &str, v: f64| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        };
        sg("venus_score_pool_workers", "Scoring-pool worker threads.", sc.workers as f64);
        sg("venus_score_pool_queue_depth", "Scoring tasks queued.", sc.queue_depth as f64);
        sg("venus_score_pool_in_flight", "Scoring tasks executing.", sc.in_flight as f64);
        sg("venus_score_pool_tasks_total", "Scoring tasks executed.", sc.tasks_total as f64);
        sg("venus_score_pool_helped_total", "Tasks drained by submitters.", sc.helped_total as f64);
        sg("venus_score_pool_batches_total", "Scatter-gather batches.", sc.batches_total as f64);
        sg("venus_score_hot_ms_total", "Cumulative hot-tier scoring ms.", sc.hot_score_ms);
        sg("venus_score_cold_ms_total", "Cumulative cold-tier scoring ms.", sc.cold_score_ms);
    }

    if let Some(tr) = tracer {
        let c = tr.counts();
        out.push_str(&format!(
            "# HELP venus_traces_finished_total Traces published by the tracer.\n\
             # TYPE venus_traces_finished_total counter\n\
             venus_traces_finished_total {}\n",
            c.finished
        ));
        out.push_str(&format!(
            "# HELP venus_traces_slow_total Traces over the slow-query bar.\n\
             # TYPE venus_traces_slow_total counter\n\
             venus_traces_slow_total {}\n",
            c.slow
        ));
        out.push_str(
            "# HELP venus_stage_duration_seconds Span-derived per-stage latency histogram.\n\
             # TYPE venus_stage_duration_seconds histogram\n",
        );
        for (name, h) in tr.stage_histograms() {
            let stage = prom_escape(&name);
            let mut cum = 0u64;
            for (i, &bound) in HIST_BOUNDS_US.iter().enumerate() {
                cum += h.buckets[i];
                out.push_str(&format!(
                    "venus_stage_duration_seconds_bucket{{stage=\"{stage}\",le=\"{}\"}} {cum}\n",
                    bound as f64 / 1_000_000.0,
                ));
            }
            cum += h.buckets[HIST_BOUNDS_US.len()];
            out.push_str(&format!(
                "venus_stage_duration_seconds_bucket{{stage=\"{stage}\",le=\"+Inf\"}} {cum}\n"
            ));
            out.push_str(&format!(
                "venus_stage_duration_seconds_sum{{stage=\"{stage}\"}} {}\n",
                h.sum_us as f64 / 1_000_000.0,
            ));
            out.push_str(&format!(
                "venus_stage_duration_seconds_count{{stage=\"{stage}\"}} {}\n",
                h.count
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Metrics;

    fn cfg(sample_n: usize, slow_ms: u64, ring: usize, slow_ring: usize) -> ObsConfig {
        ObsConfig { trace_sample_n: sample_n, slow_query_ms: slow_ms, trace_ring: ring, slow_ring }
    }

    fn finish_one(tr: &Tracer, label: &str, total: Duration) -> Option<TraceId> {
        let mut ctx = tr.mint("query", label)?;
        let t0 = ctx.started();
        ctx.record(stage::EMBED, t0, Duration::from_micros(300));
        ctx.record_counters(
            stage::SCORE,
            t0,
            Duration::from_micros(900),
            &[("rows", 128.0), ("hot_ms", 0.4)],
        );
        ctx.record_counters(stage::SCORE_SHARD, t0, Duration::from_micros(800), &[("shard", 0.0)]);
        ctx.record(stage::SELECT, t0, Duration::from_micros(50));
        Some(tr.finish(ctx, total))
    }

    #[test]
    fn trace_ids_render_and_parse() {
        let id = TraceId(42);
        assert_eq!(id.to_string(), "000000000000002a");
        assert_eq!(TraceId::parse("000000000000002a"), Some(id));
        assert_eq!(TraceId::parse("2a"), Some(id));
        assert_eq!(TraceId::parse("not hex"), None);
    }

    #[test]
    fn disabled_tracer_mints_nothing() {
        let tr = Tracer::new(&cfg(0, 500, 8, 4));
        for _ in 0..32 {
            assert!(tr.mint("query", "q").is_none());
        }
        assert_eq!(tr.counts(), ObsCounts::default());
    }

    #[test]
    fn head_sampling_honors_every_nth() {
        let tr = Tracer::new(&cfg(4, 0, 64, 4));
        let minted: Vec<bool> = (0..12).map(|_| tr.mint("query", "q").is_some()).collect();
        assert_eq!(minted.iter().filter(|&&m| m).count(), 3, "{minted:?}");
        assert!(minted[0], "the first request is always sampled");
        // sample rate 1 traces everything
        let tr = Tracer::new(&cfg(1, 0, 64, 4));
        assert!((0..8).all(|_| tr.mint("query", "q").is_some()));
    }

    #[test]
    fn finish_publishes_and_lookup_finds() {
        let tr = Tracer::new(&cfg(1, 500, 8, 4));
        let id = finish_one(&tr, "what happened", Duration::from_micros(1300)).unwrap();
        let t = tr.lookup(id).expect("published");
        assert_eq!(t.kind, "query");
        assert_eq!(t.label, "what happened");
        assert_eq!(t.total_us, 1300);
        assert_eq!(t.spans.len(), 4);
        // child spans don't count toward the stage sum
        assert_eq!(t.stage_sum_us(), 300 + 900 + 50);
        assert_eq!(t.span(stage::SCORE).unwrap().counters["rows"], 128.0);
        assert!(tr.lookup(TraceId(9999)).is_none());
        assert_eq!(tr.counts().finished, 1);
        // fast query (1.3ms) stays out of the 500ms slow ring
        assert!(tr.slow_recent(8).is_empty());
        // histograms observed embed/score/select/total, not score/shard
        let h = tr.stage_histograms();
        assert_eq!(h["embed"].count, 1);
        assert_eq!(h["score"].count, 1);
        assert_eq!(h["total"].count, 1);
        assert!(!h.contains_key("score/shard"));
        assert_eq!(h["embed"].sum_us, 300);
        assert!(h["embed"].mean_us() > 0.0);
    }

    #[test]
    fn rings_stay_bounded_under_flood() {
        let tr = Tracer::new(&cfg(1, 1, 8, 4));
        let mut first = None;
        for i in 0..100 {
            let id = finish_one(&tr, &format!("q{i}"), Duration::from_millis(2)).unwrap();
            first.get_or_insert(id);
        }
        assert_eq!(tr.recent(usize::MAX).len(), 8, "completed ring bounded");
        assert_eq!(tr.slow_recent(usize::MAX).len(), 4, "slow ring bounded");
        assert_eq!(tr.counts().finished, 100);
        assert_eq!(tr.counts().slow, 100, "all crossed the 1ms bar");
        // oldest evicted, newest retained
        assert!(tr.lookup(first.unwrap()).is_none());
        assert_eq!(tr.recent(1)[0].label, "q99");
        assert_eq!(tr.slow_recent(1)[0].label, "q99");
    }

    #[test]
    fn append_span_reaches_both_rings() {
        let tr = Tracer::new(&cfg(1, 1, 8, 4));
        let id = finish_one(&tr, "slow one", Duration::from_millis(5)).unwrap();
        tr.append_span(
            id,
            Span {
                stage: stage::GATEWAY_WRITE.into(),
                start_us: 1300,
                dur_us: 90,
                counters: BTreeMap::new(),
            },
        );
        assert!(tr.lookup(id).unwrap().span(stage::GATEWAY_WRITE).is_some());
        assert!(tr.slow_recent(1)[0].span(stage::GATEWAY_WRITE).is_some());
        // gateway I/O stages are children: rings carry them, the
        // top-level stage histograms do not
        assert!(!tr.stage_histograms().contains_key(stage::GATEWAY_WRITE));
        tr.append_span(
            id,
            Span { stage: "flush".into(), start_us: 1400, dur_us: 30, counters: BTreeMap::new() },
        );
        assert_eq!(tr.stage_histograms()["flush"].count, 1);
        // appending to an evicted/unknown id is a silent no-op
        tr.append_span(
            TraceId(77777),
            Span { stage: "x".into(), start_us: 0, dur_us: 1, counters: BTreeMap::new() },
        );
    }

    #[test]
    fn trace_json_round_trips_and_tolerates_absent_keys() {
        let tr = Tracer::new(&cfg(1, 500, 8, 4));
        let id = finish_one(&tr, "round trip", Duration::from_micros(1300)).unwrap();
        let t = tr.lookup(id).unwrap();
        let wire = t.to_json().to_string();
        let back = Trace::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, t);
        // a minimal object from an older peer still parses
        let sparse = Json::parse(r#"{"id":"2a","spans":[{"stage":"embed"}]}"#).unwrap();
        let t = Trace::from_json(&sparse).unwrap();
        assert_eq!(t.id, TraceId(42));
        assert_eq!(t.kind, "query");
        assert_eq!(t.total_us, 0);
        assert_eq!(t.spans[0].stage, "embed");
        assert_eq!(t.spans[0].dur_us, 0);
        assert!(t.spans[0].counters.is_empty());
        // missing id is the one hard error
        assert!(Trace::from_json(&Json::parse(r#"{"spans":[]}"#).unwrap()).is_err());
        assert!(Trace::from_json(&Json::parse(r#"{"id":"zz"}"#).unwrap()).is_err());
    }

    #[test]
    fn render_shows_the_breakdown_tree() {
        let tr = Tracer::new(&cfg(1, 500, 8, 4));
        let id = finish_one(&tr, "render me", Duration::from_micros(1300)).unwrap();
        let text = tr.lookup(id).unwrap().render();
        assert!(text.contains("render me"), "{text}");
        assert!(text.contains("embed"), "{text}");
        assert!(text.contains("    score/shard"), "child indented: {text}");
        assert!(text.contains("rows=128"), "{text}");
        assert!(text.contains("total 1.30ms"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_prom_output() {
        let mut h = HistSnapshot::default();
        h.observe(50); // <= 100us bucket
        h.observe(200); // <= 250us
        h.observe(7_000_000); // +Inf
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[15], 1);
        assert_eq!(h.count, 3);
        assert_eq!(h.sum_us, 7_000_250);
    }

    #[test]
    fn prometheus_text_renders_snapshot_and_histograms() {
        let m = Metrics::default();
        m.on_accepted(crate::api::Priority::Interactive);
        m.on_dequeued(crate::api::Priority::Interactive);
        m.on_completed(crate::api::Priority::Interactive, 0.001, 0.01, 0.1, 16);
        let snap = m.snapshot();
        let tr = Tracer::new(&cfg(1, 500, 8, 4));
        finish_one(&tr, "prom", Duration::from_micros(1300)).unwrap();
        let text = prometheus_text(&snap, Some(&tr));
        assert!(text.contains("# TYPE venus_uptime_seconds gauge"), "{text}");
        assert!(text.contains("venus_lane_queries_total{lane=\"interactive\",event=\"completed\"} 1"));
        assert!(text.contains("venus_latency_seconds{kind=\"total\",quantile=\"0.5\"}"));
        assert!(text.contains("venus_started_unix_ms"));
        assert!(text.contains("# TYPE venus_stage_duration_seconds histogram"));
        assert!(text.contains("venus_stage_duration_seconds_bucket{stage=\"embed\",le=\"0.0001\"}"));
        assert!(text.contains("venus_stage_duration_seconds_bucket{stage=\"total\",le=\"+Inf\"} 1"));
        assert!(text.contains("venus_stage_duration_seconds_count{stage=\"score\"} 1"));
        // every line is either a comment or `name{labels} value`
        for line in text.lines() {
            assert!(!line.is_empty());
            assert!(line.starts_with('#') || line.starts_with("venus_"), "odd line: {line}");
        }
        // without a tracer the histogram family is absent but the
        // snapshot gauges still render
        let text = prometheus_text(&snap, None);
        assert!(text.contains("venus_throughput_qps"));
        assert!(!text.contains("venus_stage_duration_seconds"));
    }

    #[test]
    fn labels_are_truncated_and_escaped() {
        let tr = Tracer::new(&cfg(1, 0, 8, 4));
        let long = "x".repeat(200);
        let ctx = tr.mint("query", &long).unwrap();
        assert_eq!(ctx.label.len(), 80);
        assert_eq!(prom_escape("a\"b\\c"), "a\\\"b\\\\c");
    }
}
