//! Adaptive Keyframe Retrieval (AKR) — threshold-driven progressive
//! sampling (§IV-D-2, Eq. 6–7).
//!
//! Draws index vectors one at a time from the Eq. 5 distribution and
//! stops as soon as the *distinct* selected indices' cumulative
//! probability reaches θ, subject to:
//!   N_min = β · ⌈θ / max_j p_j⌉   (Eq. 7 — β inflates the floor so a
//!                                  single dominant index cannot trigger
//!                                  premature termination)
//!   N_max — the transmission-delay cap from the edge-network budget.
//!
//! Note on Eq. 6: the paper writes (Σ_{j∈I} p_j)/β ≥ θ, but with the
//! paper's own β > 1 and θ = 0.9 the left side could never reach βθ > 1
//! for distinct indices; we read β's role as scaling the N_min floor
//! (Eq. 7) and apply the threshold test as Σ p_j ≥ θ.  Documented in
//! DESIGN.md §substitutions.

use crate::memory::FrameId;
use crate::util::rng::Pcg64;

use super::{sampler::softmax_probs, RecordSource, Selection};

/// AKR result with adaptivity diagnostics (Fig. 11).
#[derive(Clone, Debug, Default)]
pub struct AkrOutcome {
    pub selection: Selection,
    /// draws actually performed
    pub draws: usize,
    /// cumulative probability mass of the distinct selected indices
    pub mass: f64,
    /// the Eq. 7 lower bound that applied
    pub n_min: usize,
}

/// Run AKR over a scored memory — one shard or a merged cross-shard view
/// (the `All`-scope scatter-gather path runs AKR over the merged Eq. 5
/// distribution, so its adaptive budget reflects *total* cross-camera
/// evidence concentration).
pub fn akr_retrieve<M: RecordSource + ?Sized>(
    memory: &M,
    scores: &[f32],
    tau: f32,
    theta: f64,
    beta: f64,
    n_max: usize,
    rng: &mut Pcg64,
) -> AkrOutcome {
    assert_eq!(scores.len(), memory.len());
    if memory.is_empty() || n_max == 0 {
        return AkrOutcome::default();
    }
    let probs = softmax_probs(scores, tau);
    let p_max = probs.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let n_min = ((beta * (theta / p_max).ceil()) as usize).clamp(1, n_max);

    let mut cdf = Vec::with_capacity(probs.len());
    let mut acc = 0.0f32;
    for &p in &probs {
        acc += p;
        cdf.push(acc);
    }

    let mut selected = vec![false; probs.len()];
    let mut mass = 0.0f64;
    let mut sel = Selection { probs: probs.clone(), ..Default::default() };
    let mut counts: std::collections::BTreeMap<usize, usize> = Default::default();
    let mut draws = 0;
    while draws < n_max {
        let u = rng.f32() * acc;
        let idx = cdf.partition_point(|&c| c < u).min(cdf.len() - 1);
        draws += 1;
        sel.drawn_indices.push(idx);
        *counts.entry(idx).or_insert(0) += 1;
        if !selected[idx] {
            selected[idx] = true;
            mass += probs[idx] as f64;
        }

        if draws >= n_min && mass >= theta {
            break;
        }
    }
    // stratified per-cluster expansion, same as fixed sampling
    for (idx, k) in counts {
        // a drawn index always has a record by construction; a stale one
        // (evicted/compacted source) is skipped, not panicked on
        let Some(rec) = memory.record(idx) else { continue };
        sel.frames.extend(
            super::sampler::expand_cluster(&rec.members, k, rng)
                .into_iter()
                .map(|m| FrameId::new(rec.stream, m)),
        );
    }

    AkrOutcome { selection: sel.finalize(), draws, mass, n_min }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::{ClusterRecord, Hierarchy, InMemoryRaw, StreamId};
    use crate::video::frame::Frame;

    fn memory_with(n_clusters: usize, frames_per: u64) -> Hierarchy {
        let mut h = Hierarchy::new(
            &MemoryConfig::default(),
            4,
            Box::new(InMemoryRaw::new(8)),
        )
        .unwrap();
        for i in 0..(n_clusters as u64 * frames_per) {
            h.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        for c in 0..n_clusters {
            let mut v = vec![0.0f32; 4];
            v[c % 4] = 1.0;
            let start = c as u64 * frames_per;
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c,
                    centroid_frame: start,
                    members: (start..start + frames_per).collect(),
                },
            )
            .unwrap();
        }
        h
    }

    /// Localized query (one sharp peak): AKR stops early.
    #[test]
    fn localized_query_stops_early() {
        let h = memory_with(32, 8);
        let mut scores = vec![0.0f32; 32];
        scores[5] = 1.0;
        let mut rng = Pcg64::seeded(1);
        let out = akr_retrieve(&h, &scores, 0.03, 0.9, 2.0, 32, &mut rng);
        assert!(out.draws < 12, "draws = {}", out.draws);
        assert!(out.mass >= 0.9 || out.draws == 32);
    }

    /// Mean draws over many seeds (the sampler is randomized; single-seed
    /// comparisons of draw counts are brittle, so the adaptivity
    /// properties below are stated over seed-averaged behavior).
    fn mean_draws(
        h: &Hierarchy,
        scores: &[f32],
        tau: f32,
        theta: f64,
        n_max: usize,
        seeds: std::ops::Range<u64>,
    ) -> f64 {
        let n = (seeds.end - seeds.start) as f64;
        let total: usize = seeds
            .map(|s| akr_retrieve(h, scores, tau, theta, 2.0, n_max, &mut Pcg64::seeded(s)).draws)
            .sum();
        total as f64 / n
    }

    /// Dispersed query (flat distribution): AKR uses many more draws than
    /// a localized one-peak query, on average over seeds.
    #[test]
    fn dispersed_query_needs_more_draws() {
        let h = memory_with(32, 8);
        let localized = {
            let mut s = vec![0.0f32; 32];
            s[5] = 1.0;
            s
        };
        let dispersed = vec![0.5f32; 32];
        let a = mean_draws(&h, &localized, 0.03, 0.9, 64, 0..16);
        let b = mean_draws(&h, &dispersed, 0.03, 0.9, 64, 0..16);
        assert!(
            b > 2.0 * a,
            "dispersed mean {b:.1} vs localized mean {a:.1}"
        );
    }

    #[test]
    fn respects_n_max() {
        let h = memory_with(64, 4);
        let scores = vec![0.1f32; 64]; // uniform: mass accrues slowly
        let mut rng = Pcg64::seeded(3);
        let out = akr_retrieve(&h, &scores, 1.0, 0.99, 4.0, 16, &mut rng);
        assert_eq!(out.draws, 16);
        assert!(out.selection.frames.len() <= 16);
    }

    #[test]
    fn respects_n_min_floor() {
        // a single overwhelming peak: without the β floor, 1 draw would
        // satisfy θ; Eq. 7 forces at least β·1 draws
        let h = memory_with(16, 8);
        let mut scores = vec![-1.0f32; 16];
        scores[0] = 1.0;
        let mut rng = Pcg64::seeded(4);
        let out = akr_retrieve(&h, &scores, 0.01, 0.5, 4.0, 32, &mut rng);
        assert!(out.n_min >= 4);
        assert!(out.draws >= out.n_min, "draws {} < n_min {}", out.draws, out.n_min);
    }

    #[test]
    fn monotone_in_theta() {
        // property: higher θ ⇒ more draws on average over seeds.  (Per-seed
        // the sampler's draw sequence differs between runs, so strict
        // per-seed monotonicity is not a property of the algorithm; the
        // seed-averaged expectation is.)
        let h = memory_with(32, 8);
        let scores: Vec<f32> = (0..32).map(|i| (i as f32 * 0.7).sin() * 0.5).collect();
        let means: Vec<f64> = [0.5, 0.7, 0.9, 0.97]
            .iter()
            .map(|&theta| mean_draws(&h, &scores, 0.1, theta, 256, 0..16))
            .collect();
        for w in means.windows(2) {
            // small slack absorbs residual sampling noise on adjacent θ
            assert!(
                w[1] >= w[0] - 0.05 * w[0],
                "mean draws not monotone in θ: {means:?}"
            );
        }
        assert!(
            means[3] > means[0] * 1.5,
            "θ=0.97 should need clearly more draws than θ=0.5: {means:?}"
        );
    }

    #[test]
    fn empty_inputs() {
        let h = memory_with(4, 2);
        let mut rng = Pcg64::seeded(6);
        let out = akr_retrieve(&h, &[0.0; 4], 0.1, 0.9, 2.0, 0, &mut rng);
        assert_eq!(out.draws, 0);
        assert!(out.selection.frames.is_empty());
    }

    #[test]
    fn mass_equals_sum_of_distinct_probs() {
        let h = memory_with(16, 4);
        let scores: Vec<f32> = (0..16).map(|i| 0.05 * i as f32).collect();
        let mut rng = Pcg64::seeded(7);
        let out = akr_retrieve(&h, &scores, 0.2, 0.8, 2.0, 64, &mut rng);
        let distinct: std::collections::HashSet<usize> =
            out.selection.drawn_indices.iter().cloned().collect();
        let want: f64 = distinct.iter().map(|&i| out.selection.probs[i] as f64).sum();
        assert!((out.mass - want).abs() < 1e-9);
    }
}
