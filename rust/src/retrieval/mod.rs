//! Query-relevant keyframe retrieval (§IV-D).
//!
//! - [`sampler`]: the temperature-softmax sampling retrieval of Eq. 5 —
//!   index vectors are drawn from a query-guided distribution and each
//!   draw is expanded into a uniformly-sampled frame from the drawn
//!   vector's scene cluster (relevance + diversity).
//! - [`akr`]: Adaptive Keyframe Retrieval (Eq. 6–7) — progressive
//!   sampling that stops once the selected indices' cumulative
//!   probability clears the threshold θ, bounded by [N_min, N_max].
//! - [`topk`]: greedy Top-K retrieval (the Vanilla architecture of §III,
//!   kept as the ablation baseline for Fig. 10).

pub mod akr;
pub mod sampler;
pub mod topk;

pub use akr::{akr_retrieve, AkrOutcome};
pub use sampler::{sample_retrieve, softmax_probs, SampleOutcome};
pub use topk::topk_retrieve;

#[cfg(test)]
mod shortlist_tests {
    use super::*;

    #[test]
    fn keeps_top_m_and_masks_rest() {
        let scores = vec![0.1, 0.9, 0.5, 0.7, 0.2];
        let masked = shortlist_mask(&scores, 2);
        assert_eq!(masked[1], 0.9);
        assert_eq!(masked[3], 0.7);
        assert!(masked[0].is_infinite() && masked[2].is_infinite() && masked[4].is_infinite());
    }

    #[test]
    fn noop_when_small_or_disabled() {
        let scores = vec![0.1, 0.2];
        assert_eq!(shortlist_mask(&scores, 8), scores);
        assert_eq!(shortlist_mask(&scores, 0), scores);
    }

    #[test]
    fn softmax_over_masked_ignores_non_candidates() {
        let scores = vec![0.5f32; 100];
        let masked = shortlist_mask(
            &(0..100).map(|i| i as f32 * 0.01).collect::<Vec<_>>(),
            10,
        );
        let _ = scores;
        let p = softmax_probs(&masked, 0.2);
        let nonzero = p.iter().filter(|&&x| x > 0.0).count();
        assert_eq!(nonzero, 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}

/// Mask scores outside the top-`m` candidates to −∞ so the Eq. 5 softmax
/// concentrates on a bounded shortlist.  Without this, the match mass
/// dilutes as the index grows (hour-long streams index thousands of
/// vectors) and a fixed τ loses relevance on long videos; with it, the
/// relevance-diversity trade-off is index-size-invariant.  `m = 0`
/// disables masking.
pub fn shortlist_mask(scores: &[f32], m: usize) -> Vec<f32> {
    if m == 0 || scores.len() <= m {
        return scores.to_vec();
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut out = vec![f32::NEG_INFINITY; scores.len()];
    for &i in order.iter().take(m) {
        out[i] = scores[i];
    }
    out
}

/// A retrieval decision: which raw frames to ship to the cloud.
#[derive(Clone, Debug, Default)]
pub struct Selection {
    /// global frame ids, ascending, deduplicated
    pub frames: Vec<u64>,
    /// index-vector ids that were drawn (diagnostics / Fig. 9-10)
    pub drawn_indices: Vec<usize>,
    /// the probability distribution used (diagnostics / Fig. 9)
    pub probs: Vec<f32>,
}

impl Selection {
    pub(crate) fn finalize(mut self) -> Self {
        self.frames.sort_unstable();
        self.frames.dedup();
        self
    }
}
