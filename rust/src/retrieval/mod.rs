//! Query-relevant keyframe retrieval (§IV-D).
//!
//! - [`sampler`]: the temperature-softmax sampling retrieval of Eq. 5 —
//!   index vectors are drawn from a query-guided distribution and each
//!   draw is expanded into a uniformly-sampled frame from the drawn
//!   vector's scene cluster (relevance + diversity).
//! - [`akr`]: Adaptive Keyframe Retrieval (Eq. 6–7) — progressive
//!   sampling that stops once the selected indices' cumulative
//!   probability clears the threshold θ, bounded by [N_min, N_max].
//! - [`topk`]: greedy Top-K retrieval (the Vanilla architecture of §III,
//!   kept as the ablation baseline for Fig. 10).
//!
//! Every selector is generic over a [`RecordSource`] — either one memory
//! shard (`Hierarchy`) or a cross-shard merged view (`[&ClusterRecord]`)
//! assembled by the fabric's scatter-gather query path — and returns
//! fabric-global [`FrameId`]s, so a single selection can cite evidence
//! from several camera streams.

pub mod akr;
pub mod sampler;
pub mod topk;

pub use akr::{akr_retrieve, AkrOutcome};
pub use sampler::{sample_retrieve, softmax_probs, SampleOutcome};
pub use topk::topk_retrieve;

use crate::memory::{ClusterRecord, FrameId, Hierarchy, StreamId};

/// What a retrieval routine needs from the memory it selects over: the
/// scored records, in score-vector order.  Implemented by a single shard
/// and by the merged cross-shard record view.
///
/// `record` is total over `[0, len())` by construction (selectors only
/// draw indices they scored), but returns `Option` so a stale id — e.g. a
/// replayed selection that outlived an eviction/compaction pass — is a
/// typed miss the caller can skip or surface, never a panic inside a
/// serving worker.
pub trait RecordSource {
    fn len(&self) -> usize;

    fn record(&self, id: usize) -> Option<&ClusterRecord>;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl RecordSource for Hierarchy {
    fn len(&self) -> usize {
        Hierarchy::len(self)
    }

    fn record(&self, id: usize) -> Option<&ClusterRecord> {
        Hierarchy::record(self, id)
    }
}

/// Merged view: per-shard record slices concatenated in shard order, the
/// same order their score vectors were concatenated in.
impl<'a> RecordSource for [&'a ClusterRecord] {
    fn len(&self) -> usize {
        <[&'a ClusterRecord]>::len(self)
    }

    fn record(&self, id: usize) -> Option<&ClusterRecord> {
        self.get(id).copied()
    }
}

#[cfg(test)]
mod shortlist_tests {
    use super::*;

    #[test]
    fn keeps_top_m_and_masks_rest() {
        let scores = vec![0.1, 0.9, 0.5, 0.7, 0.2];
        let masked = shortlist_mask(&scores, 2);
        assert_eq!(masked[1], 0.9);
        assert_eq!(masked[3], 0.7);
        assert!(masked[0].is_infinite() && masked[2].is_infinite() && masked[4].is_infinite());
    }

    #[test]
    fn noop_when_small_or_disabled() {
        let scores = vec![0.1, 0.2];
        assert_eq!(shortlist_mask(&scores, 8), scores);
        assert_eq!(shortlist_mask(&scores, 0), scores);
    }

    #[test]
    fn softmax_over_masked_ignores_non_candidates() {
        let scores = vec![0.5f32; 100];
        let masked = shortlist_mask(
            &(0..100).map(|i| i as f32 * 0.01).collect::<Vec<_>>(),
            10,
        );
        let _ = scores;
        let p = softmax_probs(&masked, 0.2);
        let nonzero = p.iter().filter(|&&x| x > 0.0).count();
        assert_eq!(nonzero, 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}

/// Mask scores outside the top-`m` candidates to −∞ so the Eq. 5 softmax
/// concentrates on a bounded shortlist.  Without this, the match mass
/// dilutes as the index grows (hour-long streams index thousands of
/// vectors) and a fixed τ loses relevance on long videos; with it, the
/// relevance-diversity trade-off is index-size-invariant.  `m = 0`
/// disables masking.
pub fn shortlist_mask(scores: &[f32], m: usize) -> Vec<f32> {
    if m == 0 || scores.len() <= m {
        return scores.to_vec();
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut out = vec![f32::NEG_INFINITY; scores.len()];
    for &i in order.iter().take(m) {
        out[i] = scores[i];
    }
    out
}

/// A retrieval decision: which raw frames to ship to the cloud.
#[derive(Clone, Debug, Default)]
pub struct Selection {
    /// fabric-global frame addresses, ascending (stream-major),
    /// deduplicated
    pub frames: Vec<FrameId>,
    /// index-vector ids that were drawn, in the merged scoring order
    /// (diagnostics / Fig. 9-10)
    pub drawn_indices: Vec<usize>,
    /// the probability distribution used (diagnostics / Fig. 9)
    pub probs: Vec<f32>,
}

impl Selection {
    pub(crate) fn finalize(mut self) -> Self {
        self.frames.sort_unstable();
        self.frames.dedup();
        self
    }

    /// Stream-local frame indices, in selection order.  The single-stream
    /// view consumed by the eval harness, figures, and the answer model
    /// (which judge against one stream's script).
    pub fn frame_indices(&self) -> Vec<u64> {
        self.frames.iter().map(|f| f.idx).collect()
    }

    /// Distinct streams this selection cites, ascending.
    pub fn streams(&self) -> Vec<StreamId> {
        let mut out: Vec<StreamId> = self.frames.iter().map(|f| f.stream).collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}
