//! Sampling-based diversity-preserving retrieval (Eq. 5).

use crate::util::rng::Pcg64;
use crate::util::softmax_temp;

use super::{RecordSource, Selection};

/// Outcome of a fixed-budget sampling retrieval.
pub type SampleOutcome = Selection;

/// Eq. 5: softmax with temperature over similarity scores.
pub fn softmax_probs(scores: &[f32], tau: f32) -> Vec<f32> {
    let mut probs = vec![0.0f32; scores.len()];
    softmax_temp(scores, tau, &mut probs);
    probs
}

/// Expand a drawn index vector into `k` member frames of its cluster,
/// stratified over the cluster's temporal extent (§IV-D-1: "uniformly
/// sample n(o_i) frames from its associated scene cluster, promoting
/// diverse coverage within a cluster").  Even-spaced strata with a
/// jittered offset: spreads picks, avoids near-duplicates.
pub(crate) fn expand_cluster(members: &[u64], k: usize, rng: &mut Pcg64) -> Vec<u64> {
    let n = members.len();
    if k >= n {
        return members.to_vec();
    }
    (0..k)
        .map(|i| {
            let lo = i * n / k;
            let hi = ((i + 1) * n / k).max(lo + 1);
            members[lo + rng.range(0, hi - lo)]
        })
        .collect()
}

/// Fixed-budget sampling retrieval: draw `budget` times from the
/// query-guided distribution (Eq. 5), then expand each drawn index
/// vector into n(o_i) stratified member frames of its cluster.  Selected
/// frames carry their record's stream id, so merged cross-shard score
/// vectors yield multi-camera selections transparently.
pub fn sample_retrieve<M: RecordSource + ?Sized>(
    memory: &M,
    scores: &[f32],
    tau: f32,
    budget: usize,
    rng: &mut Pcg64,
) -> Selection {
    assert_eq!(scores.len(), memory.len());
    if memory.is_empty() || budget == 0 {
        return Selection::default();
    }
    let probs = softmax_probs(scores, tau);

    // cumulative distribution for O(log n) multinomial draws
    let mut cdf = Vec::with_capacity(probs.len());
    let mut acc = 0.0f32;
    for &p in &probs {
        acc += p;
        cdf.push(acc);
    }

    let mut sel = Selection { probs: probs.clone(), ..Default::default() };
    let mut counts: std::collections::BTreeMap<usize, usize> = Default::default();
    for _ in 0..budget {
        let u = rng.f32() * acc;
        let idx = cdf.partition_point(|&c| c < u).min(cdf.len() - 1);
        sel.drawn_indices.push(idx);
        *counts.entry(idx).or_insert(0) += 1;
    }
    for (idx, k) in counts {
        // a drawn index always has a record by construction; a stale one
        // (evicted/compacted source) is skipped, not panicked on
        let Some(rec) = memory.record(idx) else { continue };
        sel.frames.extend(
            expand_cluster(&rec.members, k, rng)
                .into_iter()
                .map(|m| crate::memory::FrameId::new(rec.stream, m)),
        );
    }
    sel.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::{ClusterRecord, FrameId, Hierarchy, InMemoryRaw, StreamId};
    use crate::video::frame::Frame;

    fn memory_with(n_clusters: usize, frames_per: u64) -> Hierarchy {
        let mut h = Hierarchy::new(
            &MemoryConfig::default(),
            4,
            Box::new(InMemoryRaw::new(8)),
        )
        .unwrap();
        for i in 0..(n_clusters as u64 * frames_per) {
            h.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        for c in 0..n_clusters {
            // orthogonal-ish unit vectors on 4 axes with sign flips
            let mut v = vec![0.0f32; 4];
            v[c % 4] = if c / 4 % 2 == 0 { 1.0 } else { -1.0 };
            let start = c as u64 * frames_per;
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c,
                    centroid_frame: start,
                    members: (start..start + frames_per).collect(),
                },
            )
            .unwrap();
        }
        h
    }

    #[test]
    fn probs_sum_to_one() {
        let p = softmax_probs(&[0.9, 0.1, 0.4], 0.1);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[0] > p[2] && p[2] > p[1]);
    }

    #[test]
    fn draws_equal_budget_and_frames_dedupe() {
        let h = memory_with(8, 10);
        let scores: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let mut rng = Pcg64::seeded(5);
        let sel = sample_retrieve(&h, &scores, 0.2, 32, &mut rng);
        assert_eq!(sel.drawn_indices.len(), 32);
        assert!(sel.frames.len() <= 32);
        assert!(sel.frames.windows(2).all(|w| w[0] < w[1]));
        // frames belong to drawn clusters (stream 0: idx encodes cluster)
        for &f in &sel.frames {
            assert_eq!(f.stream, StreamId(0));
            let cluster = (f.idx / 10) as usize;
            assert!(sel.drawn_indices.contains(&cluster));
        }
    }

    #[test]
    fn merged_record_view_tags_streams() {
        // two shards' records merged in shard order: selections must cite
        // each frame under its owning stream
        let a = memory_with(4, 5);
        let mut b = Hierarchy::for_stream(
            &MemoryConfig::default(),
            4,
            Box::new(InMemoryRaw::new(8)),
            StreamId(1),
        )
        .unwrap();
        for i in 0..20u64 {
            b.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        for c in 0..4usize {
            let mut v = vec![0.0f32; 4];
            v[c] = 1.0;
            let start = c as u64 * 5;
            b.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(1),
                    scene_id: c,
                    centroid_frame: start,
                    members: (start..start + 5).collect(),
                },
            )
            .unwrap();
        }

        let merged: Vec<&ClusterRecord> =
            a.records().iter().chain(b.records().iter()).collect();
        let scores = vec![0.5f32; merged.len()];
        let mut rng = Pcg64::seeded(11);
        let sel = sample_retrieve(&merged[..], &scores, 5.0, 64, &mut rng);
        let streams = sel.streams();
        assert_eq!(
            streams,
            vec![StreamId(0), StreamId(1)],
            "flat distribution over two shards must draw from both"
        );
        for &f in &sel.frames {
            assert!(f.idx < 20, "local idx stays in-shard: {f:?}");
        }
    }

    #[test]
    fn high_score_cluster_dominates_at_low_tau() {
        let h = memory_with(8, 10);
        let mut scores = vec![0.0f32; 8];
        scores[3] = 1.0;
        let mut rng = Pcg64::seeded(6);
        let sel = sample_retrieve(&h, &scores, 0.02, 64, &mut rng);
        let from3 = sel.drawn_indices.iter().filter(|&&i| i == 3).count();
        assert!(from3 > 60, "{from3}/64 draws from the top cluster");
    }

    #[test]
    fn high_tau_spreads_draws() {
        let h = memory_with(8, 10);
        let mut scores = vec![0.0f32; 8];
        scores[3] = 1.0;
        let mut rng = Pcg64::seeded(7);
        let sel = sample_retrieve(&h, &scores, 50.0, 64, &mut rng);
        let distinct: std::collections::HashSet<usize> =
            sel.drawn_indices.iter().cloned().collect();
        assert!(distinct.len() >= 6, "only {} clusters drawn", distinct.len());
    }

    #[test]
    fn sampling_preserves_nonzero_probability_everywhere() {
        // the paper's diversity claim: even low-scoring clusters can be
        // drawn (unlike greedy Top-K)
        let h = memory_with(4, 5);
        let scores = vec![0.9f32, 0.1, 0.1, 0.1];
        let mut seen = std::collections::HashSet::new();
        let mut rng = Pcg64::seeded(8);
        for _ in 0..50 {
            let sel = sample_retrieve(&h, &scores, 0.3, 8, &mut rng);
            seen.extend(sel.drawn_indices);
        }
        assert_eq!(seen.len(), 4, "all clusters eventually sampled");
    }

    #[test]
    fn empty_memory_or_budget() {
        let h = memory_with(2, 3);
        let mut rng = Pcg64::seeded(9);
        assert!(sample_retrieve(&h, &[0.0, 0.0], 0.1, 0, &mut rng).frames.is_empty());
    }

    #[test]
    fn deterministic_for_seed() {
        let h = memory_with(8, 10);
        let scores: Vec<f32> = (0..8).map(|i| 0.05 * i as f32).collect();
        let a = sample_retrieve(&h, &scores, 0.2, 16, &mut Pcg64::seeded(42));
        let b = sample_retrieve(&h, &scores, 0.2, 16, &mut Pcg64::seeded(42));
        assert_eq!(a.frames, b.frames);
        assert_eq!(a.drawn_indices, b.drawn_indices);
    }

    #[test]
    fn frame_ids_are_comparable_for_assertions() {
        // FrameId sorts stream-major (fabric ordering contract)
        assert!(FrameId::new(StreamId(0), 9) < FrameId::new(StreamId(1), 0));
    }
}
