//! Greedy Top-K retrieval — the Vanilla disaggregated architecture's
//! selector (§III-B), kept as the ablation baseline whose diversity
//! failure Fig. 5(b,c)/Fig. 10 demonstrates.

use crate::memory::FrameId;

use super::{RecordSource, Selection};

/// Select the K highest-scoring indexed frames (their centroid frames).
pub fn topk_retrieve<M: RecordSource + ?Sized>(
    memory: &M,
    scores: &[f32],
    k: usize,
) -> Selection {
    assert_eq!(scores.len(), memory.len());
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let mut sel = Selection::default();
    for &idx in order.iter().take(k) {
        let Some(rec) = memory.record(idx) else { continue };
        sel.drawn_indices.push(idx);
        sel.frames.push(FrameId::new(rec.stream, rec.centroid_frame));
    }
    sel.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::{ClusterRecord, Hierarchy, InMemoryRaw, StreamId};
    use crate::video::frame::Frame;

    fn memory_with(n: usize) -> Hierarchy {
        let mut h = Hierarchy::new(
            &MemoryConfig::default(),
            4,
            Box::new(InMemoryRaw::new(8)),
        )
        .unwrap();
        for i in 0..n as u64 {
            h.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        for c in 0..n {
            let mut v = vec![0.0f32; 4];
            v[c % 4] = 1.0;
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c,
                    centroid_frame: c as u64,
                    members: vec![c as u64],
                },
            )
            .unwrap();
        }
        h
    }

    fn local(sel: &Selection) -> Vec<u64> {
        sel.frame_indices()
    }

    #[test]
    fn picks_highest_scores() {
        let h = memory_with(10);
        let scores = vec![0.1, 0.9, 0.2, 0.8, 0.3, 0.0, 0.5, 0.4, 0.6, 0.7];
        let sel = topk_retrieve(&h, &scores, 3);
        let mut drawn = sel.drawn_indices.clone();
        drawn.sort_unstable();
        assert_eq!(drawn, vec![1, 3, 9]);
        assert_eq!(local(&sel), vec![1, 3, 9]);
    }

    #[test]
    fn k_exceeding_len_returns_all() {
        let h = memory_with(4);
        let sel = topk_retrieve(&h, &[0.4, 0.3, 0.2, 0.1], 10);
        assert_eq!(sel.frames.len(), 4);
    }

    #[test]
    fn greedy_concentrates_on_adjacent_peaks() {
        // the Fig. 5(b) failure mode: near-duplicate high scorers crowd
        // out other relevant regions
        let h = memory_with(20);
        let mut scores = vec![0.1f32; 20];
        for i in 5..9 {
            scores[i] = 0.9; // one dense peak
        }
        scores[15] = 0.55; // secondary relevant region
        let sel = topk_retrieve(&h, &scores, 4);
        assert!(sel.drawn_indices.iter().all(|&i| (5..9).contains(&i)));
        assert!(!sel.drawn_indices.contains(&15), "greedy ignores region 15");
    }
}
