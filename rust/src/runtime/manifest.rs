//! Artifact manifest: shapes, dtypes, and side-files emitted by
//! `python/compile/aot.py`.  The Rust runtime refuses to execute artifacts
//! whose config hash or tensor shapes do not match its expectations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::backend::ModelMeta;
use crate::util::json::Json;

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<Self> {
        Ok(Self {
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
            shape: v.get("shape")?.as_shape()?,
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// Parsed manifest.  The model hyperparameter block is decoded into the
/// backend-layer [`ModelMeta`] so artifact-backed and native backends are
/// interchangeable above this point.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config_hash: String,
    pub model: ModelMeta,
    pub entries: BTreeMap<String, EntryMeta>,
    files: BTreeMap<String, (String, Vec<usize>)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let m = v.get("model")?;
        let model = ModelMeta {
            img_size: m.get("img_size")?.as_usize()?,
            patch: m.get("patch")?.as_usize()?,
            d_embed: m.get("d_embed")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            vocab: m.get("vocab")?.as_usize()?,
            n_concepts: m.get("n_concepts")?.as_usize()?,
            concept_token_base: m.get("concept_token_base")?.as_usize()?,
            sim_rows: m.get("sim_rows")?.as_usize()?,
            scene_feat_dim: m.get("scene_feat_dim")?.as_usize()?,
            sem_weight: m.get("sem_weight")?.as_f64()? as f32,
            content_weight: m.get("content_weight")?.as_f64()? as f32,
            aux_weight: m.get("aux_weight")?.as_f64()? as f32,
        };

        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorMeta::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorMeta::parse)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntryMeta { file: e.get("file")?.as_str()?.to_string(), inputs, outputs },
            );
        }

        let mut files = BTreeMap::new();
        for (name, meta) in v.get("files")?.as_obj()? {
            files.insert(
                name.clone(),
                (
                    meta.get("file")?.as_str()?.to_string(),
                    meta.get("shape")?.as_shape()?,
                ),
            );
        }

        Ok(Self {
            dir: dir.to_path_buf(),
            config_hash: v.get("config_hash")?.as_str()?.to_string(),
            model,
            entries,
            files,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact entry '{name}' not in manifest"))
    }

    /// Which image-tower batch sizes are available, ascending.
    pub fn image_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("embed_image_b"))
            .filter_map(|b| b.parse().ok())
            .collect();
        v.sort_unstable();
        v
    }

    /// Read a little-endian f32 side file, validating element count.
    pub fn read_f32_file(&self, key: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        let (file, shape) = self
            .files
            .get(key)
            .with_context(|| format!("side file '{key}' not in manifest"))?;
        let bytes = std::fs::read(self.dir.join(file))
            .with_context(|| format!("reading side file {file}"))?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("side file {file}: {} bytes, wanted {}", bytes.len(), n * 4);
        }
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok((out, shape.clone()))
    }

    /// Read a little-endian i32 side file.
    pub fn read_i32_file(&self, key: &str) -> Result<(Vec<i32>, Vec<usize>)> {
        let (file, shape) = self
            .files
            .get(key)
            .with_context(|| format!("side file '{key}' not in manifest"))?;
        let bytes = std::fs::read(self.dir.join(file))?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("side file {file}: {} bytes, wanted {}", bytes.len(), n * 4);
        }
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok((out, shape.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parsing() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn tensor_meta_elements() {
        let t = TensorMeta { dtype: DType::F32, shape: vec![8, 64, 64, 3] };
        assert_eq!(t.elements(), 8 * 64 * 64 * 3);
    }
}
