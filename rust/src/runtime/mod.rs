//! AOT-artifact runtime layer.
//!
//! [`manifest`] (always compiled) describes the artifact set emitted by
//! `python/compile/aot.py` — shapes, dtypes, config hash, side files.  The
//! PJRT executor that actually runs those artifacts lives in [`pjrt`] and
//! is only built with the off-by-default `pjrt` cargo feature; the default
//! build serves embeddings from the self-contained native backend instead
//! (see [`crate::backend`]).

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;

pub use crate::backend::ModelMeta;
pub use manifest::{DType, EntryMeta, Manifest, TensorMeta};

#[cfg(feature = "pjrt")]
pub use pjrt::{literal_f32, literal_i32, Runtime};
