//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client from the request path.
//!
//! Artifacts are compiled lazily on first use and cached; the executables
//! are self-contained (model weights are baked in as HLO constants at
//! export time), so the only runtime inputs are frames / tokens / query
//! vectors.  Interchange is HLO *text* — serialized protos from jax ≥ 0.5
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects.
//!
//! This module is only compiled with `--features pjrt`.  The default `xla`
//! dependency is the in-tree stub (`rust/xla-stub`), which type-checks this
//! backend offline; executing real artifacts additionally requires the
//! actual `xla` bindings and a `make artifacts` run (see the Makefile).

use std::collections::HashMap;
use std::path::Path;

use crate::util::sync::{ranks, OrderedMutex};

use anyhow::{bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::backend::{EmbedBackend, ModelMeta};
use crate::runtime::manifest::Manifest;

/// Handle to the PJRT client plus the artifact set.
///
/// The compiled-executable cache is behind a `Mutex` (not `RefCell`):
/// the backend contract is `Send + Sync`, because one `Runtime` is shared
/// process-wide by every pipeline and query worker.  The lock is held for
/// compilation and the execute dispatch; XLA executions themselves are
/// reentrant on the CPU client.
///
/// Caveat for the real-bindings swap (Makefile step 2): the in-tree stub's
/// types are trivially `Send + Sync`; actual `xla` bindings wrap raw C
/// pointers and may not be.  If the real `PjRtClient`/executable types
/// lack those impls, wrap them here behind the same `Mutex` (serializing
/// execute) rather than re-introducing crate-level `unsafe impl Send` —
/// the PJRT C API's CPU client is documented thread-compatible under
/// external synchronization, which the lock provides.
pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    /// Ranked above the shard band: backend similarity calls can run
    /// under a shard guard, so this lock must be acquirable there.
    cache: OrderedMutex<HashMap<String, PjRtLoadedExecutable>>,
}

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_f32: {} elements for shape {dims:?}", data.len());
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Build an i32 literal of the given shape from a host slice.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_i32: {} elements for shape {dims:?}", data.len());
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

impl Runtime {
    /// Load the artifact directory (expects `manifest.json`; compiles
    /// nothing yet).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir.as_ref())?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: OrderedMutex::new(ranks::PJRT_EXEC_CACHE, HashMap::new()),
        })
    }

    /// Locate the artifact directory: `$VENUS_ARTIFACTS`, else
    /// `<manifest-dir>/artifacts`, else `./artifacts`.
    pub fn load_default() -> Result<Self> {
        if let Ok(dir) = std::env::var("VENUS_ARTIFACTS") {
            return Self::load(dir);
        }
        let candidates = [
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string(),
            "artifacts".to_string(),
        ];
        for c in &candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Self::load(c);
            }
        }
        bail!("no artifacts directory found (run `make artifacts`)")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model(&self) -> &ModelMeta {
        &self.manifest.model
    }

    /// Compile (or fetch from cache) an entry point.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.cache.lock().contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.cache.lock().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile a set of entries (startup warm-up for serving).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an entry with the given input literals; returns the
    /// de-tupled output literals (entries are lowered with
    /// `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.ensure_compiled(name)?;
        let entry = self.manifest.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "entry '{name}': {} inputs given, expected {}",
                inputs.len(),
                entry.inputs.len()
            );
        }
        let cache = self.cache.lock();
        let exe = cache.get(name).unwrap();
        let result = exe.execute::<Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and read all outputs back as f32 vectors.
    pub fn execute_f32(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Vec<f32>>> {
        self.execute(name, inputs)?
            .iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }

    // ---------------------------------------------------------------
    // Typed entry points
    // ---------------------------------------------------------------

    /// Image tower: `frames` is `batch × (S·S·3)` row-major pixels in
    /// [0,1]; batch must match an exported artifact (see
    /// [`Manifest::image_batches`]).  Returns `batch` embeddings of
    /// `d_embed` each (L2-normalized).
    pub fn embed_image(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        let m = self.model();
        let name = format!("embed_image_b{batch}");
        let lit = literal_f32(frames, &[batch, m.img_size, m.img_size, 3])?;
        let out = self.execute_f32(&name, &[lit])?;
        Ok(split_rows(&out[0], batch, m.d_embed))
    }

    /// Text tower (query path): one token sequence -> one embedding.
    pub fn embed_text(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = self.model();
        if tokens.len() != m.seq_len {
            bail!("embed_text: {} tokens, expected {}", tokens.len(), m.seq_len);
        }
        let lit = literal_i32(tokens, &[1, m.seq_len])?;
        let out = self.execute_f32("embed_text_b1", &[lit])?;
        Ok(out[0].clone())
    }

    /// Fused ingestion entry: frames + aux-prompt tokens (Eq. 2–3).
    pub fn embed_fused(
        &self,
        frames: &[f32],
        aux_tokens: &[i32],
        batch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let m = self.model();
        let name = format!("embed_fused_b{batch}");
        let img = literal_f32(frames, &[batch, m.img_size, m.img_size, 3])?;
        let tok = literal_i32(aux_tokens, &[batch, m.seq_len])?;
        let out = self.execute_f32(&name, &[img, tok])?;
        Ok(split_rows(&out[0], batch, m.d_embed))
    }

    /// Eq. 1 scene features for a frame batch.
    pub fn scene_features(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        let m = self.model();
        let name = format!("scene_feat_b{batch}");
        let lit = literal_f32(frames, &[batch, m.img_size, m.img_size, 3])?;
        let out = self.execute_f32(&name, &[lit])?;
        Ok(split_rows(&out[0], batch, m.scene_feat_dim))
    }

    /// Fused similarity + softmax (Eq. 4–5) over a padded index matrix.
    /// `index` must hold exactly `sim_rows × d_embed` values (pad with
    /// zero rows); returns `(scores, probs)` truncated to `n_valid`.
    pub fn similarity(
        &self,
        query: &[f32],
        index: &[f32],
        n_valid: usize,
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.model();
        if query.len() != m.d_embed {
            bail!("similarity: query dim {}", query.len());
        }
        if index.len() != m.sim_rows * m.d_embed {
            bail!(
                "similarity: index has {} values, expected {}",
                index.len(),
                m.sim_rows * m.d_embed
            );
        }
        if n_valid > m.sim_rows {
            bail!("similarity: n_valid {} > padded rows {}", n_valid, m.sim_rows);
        }
        let q = literal_f32(query, &[m.d_embed])?;
        let idx = literal_f32(index, &[m.sim_rows, m.d_embed])?;
        let tau_l = literal_f32(&[tau], &[1])?;
        let nv = literal_f32(&[n_valid as f32], &[1])?;
        let out = self.execute_f32("similarity_n1024", &[q, idx, tau_l, nv])?;
        let mut scores = out[0].clone();
        let mut probs = out[1].clone();
        scores.truncate(n_valid);
        probs.truncate(n_valid);
        Ok((scores, probs))
    }

    /// Concept pixel codes `[n_concepts][patch·patch·3]` — the watermark
    /// blocks the synthetic generator plants (shared with python).
    pub fn concept_codes(&self) -> Result<Vec<Vec<f32>>> {
        let (flat, shape) = self.manifest.read_f32_file("concept_codes")?;
        Ok(split_rows(&flat, shape[0], shape[1]))
    }

    /// Concept embedding directions `[n_concepts][d_embed]`.
    pub fn concept_dirs(&self) -> Result<Vec<Vec<f32>>> {
        let (flat, shape) = self.manifest.read_f32_file("concept_dirs")?;
        Ok(split_rows(&flat, shape[0], shape[1]))
    }
}

/// The PJRT runtime plugs into the system through the same backend trait
/// as the native implementation; everything above the engine is agnostic.
impl EmbedBackend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model(&self) -> &ModelMeta {
        &self.manifest.model
    }

    fn image_batches(&self) -> Vec<usize> {
        self.manifest.image_batches()
    }

    fn has_fused(&self, batch: usize) -> bool {
        self.manifest
            .entries
            .contains_key(&format!("embed_fused_b{batch}"))
    }

    fn warmup(&self, entries: &[&str]) -> Result<()> {
        Runtime::warmup(self, entries)
    }

    fn embed_image(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        Runtime::embed_image(self, frames, batch)
    }

    fn embed_text(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        Runtime::embed_text(self, tokens)
    }

    fn embed_fused(
        &self,
        frames: &[f32],
        aux_tokens: &[i32],
        batch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Runtime::embed_fused(self, frames, aux_tokens, batch)
    }

    fn scene_features(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        Runtime::scene_features(self, frames, batch)
    }

    fn similarity(
        &self,
        query: &[f32],
        index: &[f32],
        n_valid: usize,
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Runtime::similarity(self, query, index, n_valid, tau)
    }

    fn concept_codes(&self) -> Result<Vec<Vec<f32>>> {
        Runtime::concept_codes(self)
    }

    fn concept_dirs(&self) -> Result<Vec<Vec<f32>>> {
        Runtime::concept_dirs(self)
    }
}

fn split_rows(flat: &[f32], rows: usize, cols: usize) -> Vec<Vec<f32>> {
    assert_eq!(flat.len(), rows * cols);
    flat.chunks_exact(cols).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_shape_checked() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn literal_i32_roundtrip() {
        let l = literal_i32(&[5, 6, 7], &[3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, 6, 7]);
    }

    #[test]
    fn split_rows_chunks() {
        let v = split_rows(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(v, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }
}
