//! Serving metrics registry: counters + latency samples, shared across
//! workers, with a printable snapshot (the `venus serve` status output).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::{fmt_duration, Samples};

#[derive(Debug, Default)]
struct Inner {
    accepted: u64,
    rejected: u64,
    shutdown: u64,
    completed: u64,
    failed: u64,
    queue_wait: Samples,
    edge_latency: Samples,
    total_latency: Samples,
    frames_shipped: Samples,
}

/// Thread-safe metrics registry.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }
}

/// Immutable snapshot for reporting.  Latencies carry the p50/p95/p99
/// tail the fabric bench and Fig. 12-style reporting need — a mean hides
/// exactly the scatter-gather tail the sharded fabric is built to bound.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub accepted: u64,
    /// admission control: queue full, query turned away
    pub rejected: u64,
    /// submissions that raced service shutdown (workers gone) — distinct
    /// from `rejected` so admission-control stats stay clean
    pub shutdown: u64,
    pub completed: u64,
    pub failed: u64,
    pub uptime_s: f64,
    pub queue_wait_p50_s: f64,
    pub queue_wait_p95_s: f64,
    pub queue_wait_p99_s: f64,
    pub edge_p50_s: f64,
    pub edge_p95_s: f64,
    pub edge_p99_s: f64,
    pub total_p50_s: f64,
    pub total_p95_s: f64,
    pub total_p99_s: f64,
    pub mean_frames: f64,
    pub throughput_qps: f64,
}

impl Metrics {
    pub fn on_accepted(&self) {
        self.inner.lock().unwrap().accepted += 1;
    }

    pub fn on_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn on_shutdown_race(&self) {
        self.inner.lock().unwrap().shutdown += 1;
    }

    pub fn on_failed(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn on_completed(&self, queue_wait_s: f64, edge_s: f64, total_s: f64, frames: usize) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.queue_wait.push(queue_wait_s);
        m.edge_latency.push(edge_s);
        m.total_latency.push(total_s);
        m.frames_shipped.push(frames as f64);
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64();
        Snapshot {
            accepted: m.accepted,
            rejected: m.rejected,
            shutdown: m.shutdown,
            completed: m.completed,
            failed: m.failed,
            uptime_s: uptime,
            queue_wait_p50_s: m.queue_wait.p50(),
            queue_wait_p95_s: m.queue_wait.p95(),
            queue_wait_p99_s: m.queue_wait.p99(),
            edge_p50_s: m.edge_latency.p50(),
            edge_p95_s: m.edge_latency.p95(),
            edge_p99_s: m.edge_latency.p99(),
            total_p50_s: m.total_latency.p50(),
            total_p95_s: m.total_latency.p95(),
            total_p99_s: m.total_latency.p99(),
            mean_frames: m.frames_shipped.mean(),
            throughput_qps: if uptime > 0.0 { m.completed as f64 / uptime } else { 0.0 },
        }
    }

    /// Conservation invariant: accepted == completed + failed + in-flight.
    /// (property-tested by the server tests with in-flight == 0 at join;
    /// shutdown-raced submissions were never accepted, so they don't
    /// participate)
    pub fn conserved_after_drain(&self) -> bool {
        let m = self.inner.lock().unwrap();
        m.accepted == m.completed + m.failed
    }
}

impl Snapshot {
    pub fn render(&self) -> String {
        format!(
            "queries: {} ok / {} failed / {} rejected / {} shutdown-raced | p50 {} p95 {} p99 {} (edge p50 {} p95 {}) | {:.1} q/s | {:.1} frames/query",
            self.completed,
            self.failed,
            self.rejected,
            self.shutdown,
            fmt_duration(self.total_p50_s),
            fmt_duration(self.total_p95_s),
            fmt_duration(self.total_p99_s),
            fmt_duration(self.edge_p50_s),
            fmt_duration(self.edge_p95_s),
            self.throughput_qps,
            self.mean_frames,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::default();
        for i in 0..10 {
            m.on_accepted();
            m.on_completed(0.001, 0.01, 0.1 * (i + 1) as f64, 16);
        }
        m.on_accepted();
        m.on_failed();
        m.on_rejected();
        m.on_shutdown_race();
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.failed, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.shutdown, 1);
        assert!(s.total_p50_s >= 0.5 && s.total_p50_s <= 0.7);
        // tail ordering: p50 ≤ p95 ≤ p99 ≤ max sample
        assert!(s.total_p50_s <= s.total_p95_s);
        assert!(s.total_p95_s <= s.total_p99_s);
        assert!(s.total_p99_s <= 1.0 + 1e-9);
        assert!(s.total_p95_s >= 0.9, "p95 of 0.1..=1.0 grid is 1.0, got {}", s.total_p95_s);
        assert_eq!(s.mean_frames, 16.0);
        assert!(m.conserved_after_drain());
    }

    #[test]
    fn conservation_fails_with_inflight() {
        let m = Metrics::default();
        m.on_accepted();
        assert!(!m.conserved_after_drain());
    }

    #[test]
    fn shutdown_races_do_not_pollute_rejections() {
        let m = Metrics::default();
        m.on_shutdown_race();
        m.on_shutdown_race();
        let s = m.snapshot();
        assert_eq!(s.rejected, 0);
        assert_eq!(s.shutdown, 2);
        assert!(m.conserved_after_drain());
    }
}
