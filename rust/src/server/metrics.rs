//! Serving metrics registry: per-lane admission counters + latency
//! samples, shared across workers, with a printable snapshot (the
//! `venus serve` status output).
//!
//! Admission accounting is per priority lane (interactive / batch), and
//! deadline shedding is its own counter family — a shed query was
//! accepted but never executed, so it participates in conservation
//! (`accepted == completed + failed + deadline_shed` after drain) without
//! polluting the rejection stats.

use std::time::Instant;

use anyhow::Result;

use crate::api::Priority;
use crate::memory::TierStats;
use crate::util::json::Json;
use crate::util::stats::{fmt_bytes, fmt_duration, Samples};
use crate::util::sync::{ranks, OrderedMutex};

#[derive(Clone, Copy, Debug, Default)]
struct LaneCounters {
    accepted: u64,
    rejected: u64,
    completed: u64,
    deadline_shed: u64,
    /// popped off the lane by a worker (whatever happened next) — the
    /// live queue-depth gauge is `accepted - dequeued`
    dequeued: u64,
}

#[derive(Debug, Default)]
struct Inner {
    lanes: [LaneCounters; 2],
    shutdown: u64,
    failed: u64,
    queue_wait: Samples,
    edge_latency: Samples,
    total_latency: Samples,
    frames_shipped: Samples,
}

/// Thread-safe metrics registry.
#[derive(Debug)]
pub struct Metrics {
    /// Top of the lock order: metrics are recorded after every other
    /// guard is released, never while holding one.
    inner: OrderedMutex<Inner>,
    started: Instant,
    /// Wall-clock birth time (unix ms): lets a single `stats` reply
    /// anchor rates (QPS, ingest FPS) without a second poll.
    started_unix_ms: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            inner: OrderedMutex::new(ranks::SERVER_METRICS, Inner::default()),
            started: Instant::now(),
            started_unix_ms: now_unix_ms(),
        }
    }
}

/// Current wall-clock time in unix milliseconds (0 if the clock is
/// before the epoch — never panics on a skewed clock).
pub fn now_unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// One lane's admission/completion counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneSnapshot {
    pub accepted: u64,
    /// admission control: lane full, query turned away
    pub rejected: u64,
    pub completed: u64,
    /// accepted but shed unexecuted at dequeue time (deadline passed)
    pub deadline_shed: u64,
    /// live occupancy gauge: accepted queries a worker has not yet popped
    /// (current queue depth, not a lifetime counter)
    pub queued: u64,
}

/// Immutable snapshot for reporting.  Latency percentiles are `None`
/// until at least one query completed — a percentile over zero samples
/// is meaningless, and reporting it as `0.0` silently reads as "instant"
/// in dashboards.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub interactive: LaneSnapshot,
    pub batch: LaneSnapshot,
    /// submissions that raced service shutdown (workers gone) — distinct
    /// from `rejected` so admission-control stats stay clean
    pub shutdown: u64,
    pub failed: u64,
    pub uptime_s: f64,
    /// Uptime in integer milliseconds (same clock as `uptime_s`; rate
    /// math on the client side should prefer this).
    pub uptime_ms: u64,
    /// Wall-clock unix ms the serving process started (0 when unknown,
    /// e.g. a reply from a pre-obs server).
    pub started_unix_ms: u64,
    pub queue_wait_p50_s: Option<f64>,
    pub queue_wait_p95_s: Option<f64>,
    pub queue_wait_p99_s: Option<f64>,
    pub edge_p50_s: Option<f64>,
    pub edge_p95_s: Option<f64>,
    pub edge_p99_s: Option<f64>,
    pub total_p50_s: Option<f64>,
    pub total_p95_s: Option<f64>,
    pub total_p99_s: Option<f64>,
    pub mean_frames: f64,
    pub throughput_qps: f64,
    /// Memory-pressure gauges of the fabric this service runs over (hot
    /// bytes, cold segments, evictions, cold-hit rate, raw resident
    /// bytes).  `None` for a bare `Metrics::snapshot()`; the service
    /// fills it from its fabric — see `Service::snapshot`.
    pub memory: Option<TierStats>,
    /// Live-ingest gauges (per-stream wire counters + embed-pool queue
    /// depth and coalescing).  `None` unless the process runs a wire
    /// ingest hub; the gateway fills it into `stats` replies.
    pub ingest: Option<IngestSnapshot>,
    /// Scoring-pool gauges (worker utilization, queue depth, hot/cold
    /// scoring-time split).  `None` for a bare `Metrics::snapshot()`;
    /// the service fills it from its shared `ScorePool` — see
    /// `Service::snapshot`.
    pub scoring: Option<ScorePoolSnapshot>,
}

/// One wire-ingest stream's counters and freshness tails, as reported in
/// `stats` replies and `venus serve` output.  Populated by the ingest
/// hub (`net::wire::ingest`); defined here so `server` stays independent
/// of `net`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IngestStreamSnapshot {
    pub stream: u16,
    /// Frames accepted into the pipeline (includes not-yet-queryable).
    pub accepted: u64,
    /// The durable high-watermark: next expected sequence number.
    pub acked: u64,
    /// Frames shed under the `drop` policy (archive holes).
    pub dropped: u64,
    /// Batches answered with a `SlowDown` verdict.
    pub slowed: u64,
    /// Capture → queryable freshness percentiles, milliseconds.  `None`
    /// until the first partition of the stream becomes queryable.
    pub freshness_p50_ms: Option<f64>,
    pub freshness_p95_ms: Option<f64>,
}

/// Wire-ingest gauges: every open stream plus the shared embed pool.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IngestSnapshot {
    pub streams: Vec<IngestStreamSnapshot>,
    /// Partitions submitted to the pool but not yet picked up.
    pub pool_queue_depth: usize,
    /// Coalesced pickups (one embed call each) since start.
    pub pool_batches: usize,
    /// Mean clusters per coalesced pickup.
    pub pool_mean_batch_clusters: f64,
    /// Largest single pickup, in clusters.
    pub pool_max_batch_clusters: usize,
}

impl IngestSnapshot {
    /// Totals across streams: (accepted, dropped, slowed).
    pub fn totals(&self) -> (u64, u64, u64) {
        self.streams.iter().fold((0, 0, 0), |(a, d, s), st| {
            (a + st.accepted, d + st.dropped, s + st.slowed)
        })
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let streams: Vec<Json> = self
            .streams
            .iter()
            .map(|s| {
                let mut sm = std::collections::BTreeMap::new();
                sm.insert("stream".into(), Json::Num(s.stream as f64));
                sm.insert("accepted".into(), Json::Num(s.accepted as f64));
                sm.insert("acked".into(), Json::Num(s.acked as f64));
                sm.insert("dropped".into(), Json::Num(s.dropped as f64));
                sm.insert("slowed".into(), Json::Num(s.slowed as f64));
                if let Some(x) = s.freshness_p50_ms {
                    sm.insert("freshness_p50_ms".into(), Json::Num(x));
                }
                if let Some(x) = s.freshness_p95_ms {
                    sm.insert("freshness_p95_ms".into(), Json::Num(x));
                }
                Json::Obj(sm)
            })
            .collect();
        m.insert("streams".into(), Json::Arr(streams));
        m.insert("pool_queue_depth".into(), Json::Num(self.pool_queue_depth as f64));
        m.insert("pool_batches".into(), Json::Num(self.pool_batches as f64));
        m.insert(
            "pool_mean_batch_clusters".into(),
            Json::Num(self.pool_mean_batch_clusters),
        );
        m.insert(
            "pool_max_batch_clusters".into(),
            Json::Num(self.pool_max_batch_clusters as f64),
        );
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let streams = v
            .get("streams")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(IngestStreamSnapshot {
                    stream: s.get("stream")?.as_usize()? as u16,
                    accepted: s.get("accepted")?.as_usize()? as u64,
                    acked: s.get("acked")?.as_usize()? as u64,
                    dropped: s.get("dropped")?.as_usize()? as u64,
                    slowed: s.get("slowed")?.as_usize()? as u64,
                    freshness_p50_ms: s.opt("freshness_p50_ms").map(|x| x.as_f64()).transpose()?,
                    freshness_p95_ms: s.opt("freshness_p95_ms").map(|x| x.as_f64()).transpose()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            streams,
            pool_queue_depth: v.get("pool_queue_depth")?.as_usize()?,
            pool_batches: v.get("pool_batches")?.as_usize()?,
            pool_mean_batch_clusters: v.get("pool_mean_batch_clusters")?.as_f64()?,
            pool_max_batch_clusters: v.get("pool_max_batch_clusters")?.as_usize()?,
        })
    }

    pub fn render(&self) -> String {
        let opt = |x: Option<f64>| {
            x.map(|v| format!("{v:.0}ms")).unwrap_or_else(|| "n/a".into())
        };
        let mut out = format!(
            "ingest: pool q{} / {} batches (mean {:.1}, max {} clusters)",
            self.pool_queue_depth,
            self.pool_batches,
            self.pool_mean_batch_clusters,
            self.pool_max_batch_clusters,
        );
        for s in &self.streams {
            out.push_str(&format!(
                " | s{}: {} acc, {} ack, {} drop, {} slow, fresh p50 {} p95 {}",
                s.stream,
                s.accepted,
                s.acked,
                s.dropped,
                s.slowed,
                opt(s.freshness_p50_ms),
                opt(s.freshness_p95_ms),
            ));
        }
        out
    }
}

/// Scoring-pool gauges: worker count and live load, lifetime task
/// counters, and the hot-vs-cold scoring-time split.  Mirrors
/// `util::scorer::PoolGauges`; defined here so `server` owns its wire
/// schema and `util` stays wire-agnostic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScorePoolSnapshot {
    /// Fixed worker-thread count the pool was built with.
    pub workers: u64,
    /// Tasks enqueued but not yet picked up (live gauge).
    pub queue_depth: u64,
    /// Tasks currently executing on workers or helpers (live gauge).
    pub in_flight: u64,
    /// Tasks executed since start (includes prefetches).
    pub tasks_total: u64,
    /// Tasks the submitting thread drained itself while waiting.
    pub helped_total: u64,
    /// Scatter-gather batches (one per pooled query scoring pass).
    pub batches_total: u64,
    /// Cumulative milliseconds spent scoring hot-index rows.
    pub hot_score_ms: f64,
    /// Cumulative milliseconds spent scoring cold-segment rows.
    pub cold_score_ms: f64,
}

impl ScorePoolSnapshot {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("queue_depth".into(), Json::Num(self.queue_depth as f64));
        m.insert("in_flight".into(), Json::Num(self.in_flight as f64));
        m.insert("tasks_total".into(), Json::Num(self.tasks_total as f64));
        m.insert("helped_total".into(), Json::Num(self.helped_total as f64));
        m.insert("batches_total".into(), Json::Num(self.batches_total as f64));
        m.insert("hot_score_ms".into(), Json::Num(self.hot_score_ms));
        m.insert("cold_score_ms".into(), Json::Num(self.cold_score_ms));
        Json::Obj(m)
    }

    /// Tolerant parse: every key optional, so a new client can read an
    /// old server's `stats` reply (and vice versa) without erroring.
    pub fn from_json(v: &Json) -> Result<Self> {
        let num = |key: &str| -> Result<u64> {
            Ok(v.opt(key).map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64)
        };
        let fnum = |key: &str| -> Result<f64> {
            Ok(v.opt(key).map(|x| x.as_f64()).transpose()?.unwrap_or(0.0))
        };
        Ok(Self {
            workers: num("workers")?,
            queue_depth: num("queue_depth")?,
            in_flight: num("in_flight")?,
            tasks_total: num("tasks_total")?,
            helped_total: num("helped_total")?,
            batches_total: num("batches_total")?,
            hot_score_ms: fnum("hot_score_ms")?,
            cold_score_ms: fnum("cold_score_ms")?,
        })
    }

    pub fn render(&self) -> String {
        format!(
            "scoring: {}w q{} / {} in-flight / {} tasks ({} helped) / {} batches / hot {:.1}ms cold {:.1}ms",
            self.workers,
            self.queue_depth,
            self.in_flight,
            self.tasks_total,
            self.helped_total,
            self.batches_total,
            self.hot_score_ms,
            self.cold_score_ms,
        )
    }
}

impl Metrics {
    pub fn on_accepted(&self, lane: Priority) {
        self.inner.lock().lanes[lane.index()].accepted += 1;
    }

    pub fn on_rejected(&self, lane: Priority) {
        self.inner.lock().lanes[lane.index()].rejected += 1;
    }

    pub fn on_shutdown_race(&self) {
        self.inner.lock().shutdown += 1;
    }

    pub fn on_failed(&self) {
        self.inner.lock().failed += 1;
    }

    pub fn on_deadline_shed(&self, lane: Priority) {
        self.inner.lock().lanes[lane.index()].deadline_shed += 1;
    }

    /// A worker popped a job off its lane (it will complete, fail, or be
    /// deadline-shed next) — decrements the live queue-depth gauge.
    pub fn on_dequeued(&self, lane: Priority) {
        self.inner.lock().lanes[lane.index()].dequeued += 1;
    }

    pub fn on_completed(
        &self,
        lane: Priority,
        queue_wait_s: f64,
        edge_s: f64,
        total_s: f64,
        frames: usize,
    ) {
        let mut m = self.inner.lock();
        m.lanes[lane.index()].completed += 1;
        m.queue_wait.push(queue_wait_s);
        m.edge_latency.push(edge_s);
        m.total_latency.push(total_s);
        m.frames_shipped.push(frames as f64);
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock();
        let elapsed = self.started.elapsed();
        let uptime = elapsed.as_secs_f64();
        let pct = |s: &Samples, q: f64| -> Option<f64> {
            if s.is_empty() {
                None
            } else {
                Some(s.percentile(q))
            }
        };
        let lane = |i: usize| LaneSnapshot {
            accepted: m.lanes[i].accepted,
            rejected: m.lanes[i].rejected,
            completed: m.lanes[i].completed,
            deadline_shed: m.lanes[i].deadline_shed,
            queued: m.lanes[i].accepted.saturating_sub(m.lanes[i].dequeued),
        };
        let completed: u64 = m.lanes.iter().map(|l| l.completed).sum();
        Snapshot {
            interactive: lane(Priority::Interactive.index()),
            batch: lane(Priority::Batch.index()),
            shutdown: m.shutdown,
            failed: m.failed,
            uptime_s: uptime,
            uptime_ms: elapsed.as_millis() as u64,
            started_unix_ms: self.started_unix_ms,
            queue_wait_p50_s: pct(&m.queue_wait, 50.0),
            queue_wait_p95_s: pct(&m.queue_wait, 95.0),
            queue_wait_p99_s: pct(&m.queue_wait, 99.0),
            edge_p50_s: pct(&m.edge_latency, 50.0),
            edge_p95_s: pct(&m.edge_latency, 95.0),
            edge_p99_s: pct(&m.edge_latency, 99.0),
            total_p50_s: pct(&m.total_latency, 50.0),
            total_p95_s: pct(&m.total_latency, 95.0),
            total_p99_s: pct(&m.total_latency, 99.0),
            mean_frames: m.frames_shipped.mean(),
            throughput_qps: if uptime > 0.0 { completed as f64 / uptime } else { 0.0 },
            memory: None,
            ingest: None,
            scoring: None,
        }
    }

    /// Live queue depth of one lane (accepted − dequeued): the cheap
    /// contention signal the wire-ingest admission controller polls per
    /// batch — a full snapshot would clone every latency sample ring.
    pub fn queued_depth(&self, lane: Priority) -> u64 {
        let m = self.inner.lock();
        let l = &m.lanes[lane.index()];
        l.accepted.saturating_sub(l.dequeued)
    }

    /// Conservation invariant after drain: every accepted query either
    /// completed, failed, or was deadline-shed.  (Shutdown-raced and
    /// rejected submissions were never accepted, so they don't
    /// participate.)
    pub fn conserved_after_drain(&self) -> bool {
        let m = self.inner.lock();
        let accepted: u64 = m.lanes.iter().map(|l| l.accepted).sum();
        let settled: u64 =
            m.lanes.iter().map(|l| l.completed + l.deadline_shed).sum::<u64>() + m.failed;
        accepted == settled
    }
}

impl Snapshot {
    pub fn accepted(&self) -> u64 {
        self.interactive.accepted + self.batch.accepted
    }

    pub fn rejected(&self) -> u64 {
        self.interactive.rejected + self.batch.rejected
    }

    pub fn completed(&self) -> u64 {
        self.interactive.completed + self.batch.completed
    }

    pub fn deadline_shed(&self) -> u64 {
        self.interactive.deadline_shed + self.batch.deadline_shed
    }

    /// Live occupancy across both lanes (current queue depth).
    pub fn queued(&self) -> u64 {
        self.interactive.queued + self.batch.queued
    }

    /// QPS derived from this one reply (completed ÷ uptime), preferring
    /// the integer millisecond clock.  Falls back to the server-computed
    /// `throughput_qps` when the reply predates `uptime_ms`.
    pub fn derived_qps(&self) -> f64 {
        if self.uptime_ms > 0 {
            self.completed() as f64 / (self.uptime_ms as f64 / 1000.0)
        } else {
            self.throughput_qps
        }
    }

    pub fn render(&self) -> String {
        let opt = |d: Option<f64>| d.map(fmt_duration).unwrap_or_else(|| "n/a".into());
        let mut out = format!(
            "queries: {} ok / {} failed / {} rejected / {} deadline-shed / {} shutdown-raced | lanes: interactive {}/{} q{} batch {}/{} q{} (done/accepted/queued) | p50 {} p95 {} p99 {} (edge p50 {} p95 {}) | {:.1} q/s | {:.1} frames/query",
            self.completed(),
            self.failed,
            self.rejected(),
            self.deadline_shed(),
            self.shutdown,
            self.interactive.completed,
            self.interactive.accepted,
            self.interactive.queued,
            self.batch.completed,
            self.batch.accepted,
            self.batch.queued,
            opt(self.total_p50_s),
            opt(self.total_p95_s),
            opt(self.total_p99_s),
            opt(self.edge_p50_s),
            opt(self.edge_p95_s),
            self.throughput_qps,
            self.mean_frames,
        );
        if let Some(m) = &self.memory {
            let hit = m
                .cold_hit_rate()
                .map(|r| format!("{:.0}%", r * 100.0))
                .unwrap_or_else(|| "n/a".into());
            out.push_str(&format!(
                " | mem: hot {} ({} rec) / cold {} seg ({} rec, {} resident, hit {hit}) / raw {} / {} evicted",
                fmt_bytes(m.hot_bytes),
                m.hot_records,
                m.cold_segments,
                m.cold_records,
                fmt_bytes(m.cold_resident_bytes),
                fmt_bytes(m.raw_resident_bytes),
                m.evictions,
            ));
            // cold-scan observability: probe selectivity + rows scored +
            // the scan representation (exact f32 vs quantized SQ8)
            if m.cold_probe_candidates > 0 {
                out.push_str(&format!(
                    " / scan {}/{} seg, {} rows, {}",
                    m.cold_probe_segments,
                    m.cold_probe_candidates,
                    m.cold_rows_scored,
                    if m.cold_quantized { "sq8" } else { "exact" },
                ));
            }
        }
        if let Some(ing) = &self.ingest {
            out.push_str(" | ");
            out.push_str(&ing.render());
        }
        if let Some(sc) = &self.scoring {
            out.push_str(" | ");
            out.push_str(&sc.render());
        }
        out
    }

    /// Serialize to the wire JSON encoding (the gateway's `Stats` reply).
    /// Absent keys encode `None`; the live queue-depth gauges ride along
    /// per lane.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let lane_json = |l: &LaneSnapshot| {
            let mut lm = std::collections::BTreeMap::new();
            lm.insert("accepted".into(), Json::Num(l.accepted as f64));
            lm.insert("rejected".into(), Json::Num(l.rejected as f64));
            lm.insert("completed".into(), Json::Num(l.completed as f64));
            lm.insert("deadline_shed".into(), Json::Num(l.deadline_shed as f64));
            lm.insert("queued".into(), Json::Num(l.queued as f64));
            Json::Obj(lm)
        };
        m.insert("interactive".into(), lane_json(&self.interactive));
        m.insert("batch".into(), lane_json(&self.batch));
        m.insert("shutdown".into(), Json::Num(self.shutdown as f64));
        m.insert("failed".into(), Json::Num(self.failed as f64));
        m.insert("uptime_s".into(), Json::Num(self.uptime_s));
        m.insert("uptime_ms".into(), Json::Num(self.uptime_ms as f64));
        m.insert("started_unix_ms".into(), Json::Num(self.started_unix_ms as f64));
        let mut opt = |key: &str, v: Option<f64>| {
            if let Some(x) = v {
                m.insert(key.into(), Json::Num(x));
            }
        };
        opt("queue_wait_p50_s", self.queue_wait_p50_s);
        opt("queue_wait_p95_s", self.queue_wait_p95_s);
        opt("queue_wait_p99_s", self.queue_wait_p99_s);
        opt("edge_p50_s", self.edge_p50_s);
        opt("edge_p95_s", self.edge_p95_s);
        opt("edge_p99_s", self.edge_p99_s);
        opt("total_p50_s", self.total_p50_s);
        opt("total_p95_s", self.total_p95_s);
        opt("total_p99_s", self.total_p99_s);
        m.insert("mean_frames".into(), Json::Num(self.mean_frames));
        m.insert("throughput_qps".into(), Json::Num(self.throughput_qps));
        if let Some(mem) = &self.memory {
            m.insert("memory".into(), mem.to_json());
        }
        if let Some(ing) = &self.ingest {
            m.insert("ingest".into(), ing.to_json());
        }
        if let Some(sc) = &self.scoring {
            m.insert("scoring".into(), sc.to_json());
        }
        Json::Obj(m)
    }

    /// Parse the wire JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let lane = |v: &Json| -> Result<LaneSnapshot> {
            Ok(LaneSnapshot {
                accepted: v.get("accepted")?.as_usize()? as u64,
                rejected: v.get("rejected")?.as_usize()? as u64,
                completed: v.get("completed")?.as_usize()? as u64,
                deadline_shed: v.get("deadline_shed")?.as_usize()? as u64,
                queued: v.get("queued")?.as_usize()? as u64,
            })
        };
        let opt = |key: &str| -> Result<Option<f64>> {
            v.opt(key).map(|x| x.as_f64()).transpose()
        };
        Ok(Self {
            interactive: lane(v.get("interactive")?)?,
            batch: lane(v.get("batch")?)?,
            shutdown: v.get("shutdown")?.as_usize()? as u64,
            failed: v.get("failed")?.as_usize()? as u64,
            uptime_s: v.get("uptime_s")?.as_f64()?,
            // absent on pre-obs servers: tolerate, don't error
            uptime_ms: v.opt("uptime_ms").map(|x| x.as_usize()).transpose()?.unwrap_or(0) as u64,
            started_unix_ms: v
                .opt("started_unix_ms")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0) as u64,
            queue_wait_p50_s: opt("queue_wait_p50_s")?,
            queue_wait_p95_s: opt("queue_wait_p95_s")?,
            queue_wait_p99_s: opt("queue_wait_p99_s")?,
            edge_p50_s: opt("edge_p50_s")?,
            edge_p95_s: opt("edge_p95_s")?,
            edge_p99_s: opt("edge_p99_s")?,
            total_p50_s: opt("total_p50_s")?,
            total_p95_s: opt("total_p95_s")?,
            total_p99_s: opt("total_p99_s")?,
            mean_frames: v.get("mean_frames")?.as_f64()?,
            throughput_qps: v.get("throughput_qps")?.as_f64()?,
            memory: v.opt("memory").map(TierStats::from_json).transpose()?,
            ingest: v.opt("ingest").map(IngestSnapshot::from_json).transpose()?,
            scoring: v.opt("scoring").map(ScorePoolSnapshot::from_json).transpose()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::default();
        for i in 0..10 {
            m.on_accepted(Priority::Interactive);
            m.on_completed(Priority::Interactive, 0.001, 0.01, 0.1 * (i + 1) as f64, 16);
        }
        m.on_accepted(Priority::Batch);
        m.on_failed();
        m.on_rejected(Priority::Batch);
        m.on_shutdown_race();
        let s = m.snapshot();
        assert_eq!(s.completed(), 10);
        assert_eq!(s.interactive.completed, 10);
        assert_eq!(s.batch.accepted, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.rejected(), 1);
        assert_eq!(s.batch.rejected, 1);
        assert_eq!(s.shutdown, 1);
        let p50 = s.total_p50_s.unwrap();
        assert!((0.5..=0.7).contains(&p50));
        // tail ordering: p50 ≤ p95 ≤ p99 ≤ max sample
        assert!(s.total_p50_s <= s.total_p95_s);
        assert!(s.total_p95_s <= s.total_p99_s);
        assert!(s.total_p99_s.unwrap() <= 1.0 + 1e-9);
        assert!(s.total_p95_s.unwrap() >= 0.9);
        assert_eq!(s.mean_frames, 16.0);
        assert!(m.conserved_after_drain());
    }

    #[test]
    fn empty_snapshot_reports_no_percentiles() {
        // zero completed queries: every percentile is None (not a silent
        // 0.0 that reads as "instant"), counters are zero, render says n/a
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.completed(), 0);
        assert_eq!(s.total_p50_s, None);
        assert_eq!(s.total_p95_s, None);
        assert_eq!(s.total_p99_s, None);
        assert_eq!(s.edge_p50_s, None);
        assert_eq!(s.queue_wait_p99_s, None);
        assert_eq!(s.mean_frames, 0.0);
        assert!(s.render().contains("n/a"));
        assert!(m.conserved_after_drain());
    }

    #[test]
    fn memory_gauges_render_when_present() {
        let m = Metrics::default();
        let mut s = m.snapshot();
        assert!(s.memory.is_none(), "bare snapshot carries no fabric gauges");
        assert!(!s.render().contains("mem:"));
        s.memory = Some(TierStats {
            hot_bytes: 2048,
            hot_records: 10,
            cold_records: 30,
            cold_segments: 3,
            cold_resident_bytes: 1024,
            raw_resident_bytes: 0,
            evictions: 30,
            cold_hits: 9,
            cold_misses: 1,
            cold_probe_segments: 4,
            cold_probe_candidates: 12,
            cold_rows_scored: 120,
            cold_quantized: true,
        });
        let text = s.render();
        assert!(text.contains("mem: hot 2.0 KiB (10 rec)"), "{text}");
        assert!(text.contains("cold 3 seg (30 rec"), "{text}");
        assert!(text.contains("hit 90%"), "{text}");
        assert!(text.contains("30 evicted"), "{text}");
        assert!(text.contains("scan 4/12 seg, 120 rows, sq8"), "{text}");
    }

    #[test]
    fn queue_depth_gauges_track_live_occupancy() {
        let m = Metrics::default();
        for _ in 0..3 {
            m.on_accepted(Priority::Interactive);
        }
        m.on_accepted(Priority::Batch);
        let s = m.snapshot();
        assert_eq!(s.interactive.queued, 3, "accepted, not yet popped");
        assert_eq!(s.batch.queued, 1);
        assert_eq!(s.queued(), 4);
        assert!(s.render().contains("interactive 0/3 q3"), "{}", s.render());

        m.on_dequeued(Priority::Interactive);
        m.on_completed(Priority::Interactive, 0.0, 0.01, 0.02, 4);
        m.on_dequeued(Priority::Batch);
        m.on_deadline_shed(Priority::Batch);
        let s = m.snapshot();
        assert_eq!(s.interactive.queued, 2, "one popped");
        assert_eq!(s.batch.queued, 0, "shed queries left the queue too");
        // rejected submissions never entered the queue: gauge unchanged
        m.on_rejected(Priority::Interactive);
        assert_eq!(m.snapshot().interactive.queued, 2);
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let m = Metrics::default();
        m.on_accepted(Priority::Interactive);
        m.on_accepted(Priority::Interactive);
        m.on_dequeued(Priority::Interactive);
        m.on_completed(Priority::Interactive, 0.001, 0.01, 0.1, 16);
        m.on_rejected(Priority::Batch);
        let mut s = m.snapshot();
        s.memory = Some(TierStats {
            hot_bytes: 2048,
            hot_records: 10,
            cold_records: 30,
            cold_segments: 3,
            cold_resident_bytes: 1024,
            raw_resident_bytes: 512,
            evictions: 30,
            cold_hits: 9,
            cold_misses: 1,
            cold_probe_segments: 4,
            cold_probe_candidates: 12,
            cold_rows_scored: 120,
            cold_quantized: true,
        });
        let wire = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.interactive.accepted, 2);
        assert_eq!(back.interactive.completed, 1);
        assert_eq!(back.interactive.queued, 1);
        assert_eq!(back.batch.rejected, 1);
        assert_eq!(back.total_p50_s, s.total_p50_s);
        assert_eq!(back.queue_wait_p99_s, s.queue_wait_p99_s);
        let mem = back.memory.expect("memory gauges survive the wire");
        assert_eq!(mem.hot_bytes, 2048);
        assert_eq!(mem.cold_hits, 9);
        assert_eq!(mem.cold_probe_segments, 4);
        assert_eq!(mem.cold_probe_candidates, 12);
        assert_eq!(mem.cold_rows_scored, 120);
        assert!(mem.cold_quantized);

        // None percentiles stay None through the wire (absent keys)
        let empty = Metrics::default().snapshot();
        let back = Snapshot::from_json(&Json::parse(&empty.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.total_p50_s, None);
        assert!(back.memory.is_none());
    }

    #[test]
    fn ingest_gauges_render_and_round_trip() {
        let m = Metrics::default();
        let mut s = m.snapshot();
        assert!(s.ingest.is_none(), "bare snapshot carries no ingest gauges");
        assert!(!s.render().contains("ingest:"));
        s.ingest = Some(IngestSnapshot {
            streams: vec![
                IngestStreamSnapshot {
                    stream: 0,
                    accepted: 480,
                    acked: 480,
                    dropped: 0,
                    slowed: 3,
                    freshness_p50_ms: Some(850.0),
                    freshness_p95_ms: Some(2100.0),
                },
                IngestStreamSnapshot {
                    stream: 1,
                    accepted: 100,
                    acked: 132,
                    dropped: 32,
                    slowed: 0,
                    freshness_p50_ms: None,
                    freshness_p95_ms: None,
                },
            ],
            pool_queue_depth: 2,
            pool_batches: 17,
            pool_mean_batch_clusters: 6.5,
            pool_max_batch_clusters: 8,
        });
        let text = s.render();
        assert!(text.contains("ingest: pool q2 / 17 batches"), "{text}");
        assert!(text.contains("s0: 480 acc, 480 ack, 0 drop, 3 slow"), "{text}");
        assert!(text.contains("fresh p50 850ms p95 2100ms"), "{text}");
        assert!(text.contains("s1: 100 acc, 132 ack, 32 drop, 0 slow"), "{text}");
        assert!(text.contains("p50 n/a"), "{text}");

        let wire = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
        let ing = back.ingest.expect("ingest gauges survive the wire");
        assert_eq!(ing, s.ingest.unwrap());
        assert_eq!(ing.totals(), (580, 32, 3));
    }

    #[test]
    fn scoring_gauges_render_and_round_trip() {
        let m = Metrics::default();
        let mut s = m.snapshot();
        assert!(s.scoring.is_none(), "bare snapshot carries no pool gauges");
        assert!(!s.render().contains("scoring:"));
        s.scoring = Some(ScorePoolSnapshot {
            workers: 4,
            queue_depth: 2,
            in_flight: 3,
            tasks_total: 960,
            helped_total: 41,
            batches_total: 120,
            hot_score_ms: 12.5,
            cold_score_ms: 340.0,
        });
        let text = s.render();
        assert!(text.contains("scoring: 4w q2 / 3 in-flight"), "{text}");
        assert!(text.contains("960 tasks (41 helped)"), "{text}");
        assert!(text.contains("hot 12.5ms cold 340.0ms"), "{text}");

        let wire = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
        let sc = back.scoring.expect("pool gauges survive the wire");
        assert_eq!(sc, s.scoring.unwrap());

        // tolerance: an old server's reply lacks newer keys entirely —
        // parse yields zeros instead of an error
        let sparse = Json::parse(r#"{"workers": 2}"#).unwrap();
        let sc = ScorePoolSnapshot::from_json(&sparse).unwrap();
        assert_eq!(sc.workers, 2);
        assert_eq!(sc.tasks_total, 0);
        assert_eq!(sc.cold_score_ms, 0.0);
    }

    #[test]
    fn uptime_clock_survives_the_wire_and_derives_qps() {
        let m = Metrics::default();
        m.on_accepted(Priority::Interactive);
        m.on_dequeued(Priority::Interactive);
        m.on_completed(Priority::Interactive, 0.0, 0.01, 0.02, 4);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let s = m.snapshot();
        assert!(s.uptime_ms >= 5, "uptime_ms tracks the monotonic clock: {}", s.uptime_ms);
        assert!(s.started_unix_ms > 0, "wall-clock birth time is stamped");
        assert!(s.derived_qps() > 0.0);
        let wire = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.uptime_ms, s.uptime_ms);
        assert_eq!(back.started_unix_ms, s.started_unix_ms);
        // a pre-obs server's reply lacks both keys: parse tolerates and
        // derived_qps falls back to the server-computed rate
        let mut legacy = s.to_json();
        if let Json::Obj(map) = &mut legacy {
            map.remove("uptime_ms");
            map.remove("started_unix_ms");
        }
        let back = Snapshot::from_json(&Json::parse(&legacy.to_string()).unwrap()).unwrap();
        assert_eq!(back.uptime_ms, 0);
        assert_eq!(back.started_unix_ms, 0);
        assert_eq!(back.derived_qps(), back.throughput_qps);
    }

    #[test]
    fn queued_depth_is_the_live_lane_gauge() {
        let m = Metrics::default();
        assert_eq!(m.queued_depth(Priority::Interactive), 0);
        m.on_accepted(Priority::Interactive);
        m.on_accepted(Priority::Interactive);
        m.on_accepted(Priority::Batch);
        assert_eq!(m.queued_depth(Priority::Interactive), 2);
        assert_eq!(m.queued_depth(Priority::Batch), 1);
        m.on_dequeued(Priority::Interactive);
        assert_eq!(m.queued_depth(Priority::Interactive), 1);
    }

    #[test]
    fn conservation_fails_with_inflight() {
        let m = Metrics::default();
        m.on_accepted(Priority::Interactive);
        assert!(!m.conserved_after_drain());
    }

    #[test]
    fn deadline_shed_participates_in_conservation() {
        let m = Metrics::default();
        m.on_accepted(Priority::Batch);
        m.on_accepted(Priority::Interactive);
        m.on_deadline_shed(Priority::Batch);
        assert!(!m.conserved_after_drain(), "one query still in flight");
        m.on_completed(Priority::Interactive, 0.0, 0.01, 0.02, 4);
        assert!(m.conserved_after_drain());
        let s = m.snapshot();
        assert_eq!(s.deadline_shed(), 1);
        assert_eq!(s.batch.deadline_shed, 1);
        assert_eq!(s.rejected(), 0, "shedding is not a rejection");
    }

    #[test]
    fn shutdown_races_do_not_pollute_rejections() {
        let m = Metrics::default();
        m.on_shutdown_race();
        m.on_shutdown_race();
        let s = m.snapshot();
        assert_eq!(s.rejected(), 0);
        assert_eq!(s.shutdown, 2);
        assert!(m.conserved_after_drain());
    }
}
