//! Online serving loop: multi-worker query service behind the typed
//! Serving API v1 — priority-lane admission, deadline-aware shedding,
//! a fabric-wide semantic query cache, per-query latency accounting,
//! and a metrics registry.
//!
//! Worker threads each own a cheap query-engine front-end over the ONE
//! process-shared embed backend (`backend::shared_default`) and the
//! shared memory fabric — backends are never rebuilt per worker.
//!
//! Admission: queries enter one of two bounded lanes by
//! [`Priority`](crate::api::Priority) — interactive traffic is always
//! dequeued before batch traffic, and each lane rejects independently
//! when full ([`ApiError::Rejected`]), so a flood of batch analytics can
//! never starve or reject a human's query.  A request whose deadline
//! passed while it sat queued is *shed at dequeue time* without
//! executing ([`ApiError::DeadlineExceeded`], the `deadline_shed`
//! metric): under overload the worker pool stops burning edge compute on
//! answers nobody is waiting for.  A submission that races service
//! shutdown reports [`ApiError::Shutdown`] — a distinct condition, so
//! admission-control stats stay clean.
//!
//! Every worker shares one [`QueryCache`]: repeat and near-duplicate
//! queries (the dominant pattern in online video QA traffic) skip the
//! embed + scatter-gather hot path entirely — see
//! [`crate::api::cache`] for the reuse/staleness protocol.

pub mod metrics;

pub use metrics::{
    now_unix_ms, IngestSnapshot, IngestStreamSnapshot, LaneSnapshot, Metrics, ScorePoolSnapshot,
    Snapshot,
};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::api::cache::QueryCache;
use crate::api::{ApiError, Evidence, Priority, QueryRequest, QueryResponse};
use crate::backend;
use crate::cloud::VlmClient;
use crate::config::VenusConfig;
use crate::coordinator::query::{QueryEngine, QueryOutcome};
use crate::embed::EmbedEngine;
use crate::memory::MemoryFabric;
use crate::net::{Link, Payload};
use crate::obs::{stage, TraceCtx, TraceId, Tracer};
use crate::util::scorer::ScorePool;
use crate::util::sync::{ranks, OrderedCondvar, OrderedMutex};

struct Job {
    id: u64,
    request: QueryRequest,
    enqueued: Instant,
    /// absolute deadline resolved at submission
    deadline: Option<Instant>,
    reply: SyncSender<Result<QueryResponse, ApiError>>,
    /// per-request span collector, minted at admission when this request
    /// was head-sampled (`None` ⇒ untraced, zero overhead downstream)
    trace: Option<TraceCtx>,
}

/// Two bounded FIFO lanes under one condvar: interactive pops first.
/// The lane mutex is a leaf in the lock order — nothing else is
/// acquired while it is held.
struct Lanes {
    state: OrderedMutex<LaneState>,
    cv: OrderedCondvar,
    depth: [usize; 2],
}

struct LaneState {
    queues: [VecDeque<Job>; 2],
    open: bool,
}

enum PushError {
    Full,
    Closed,
}

impl Lanes {
    fn new(interactive_depth: usize, batch_depth: usize) -> Self {
        Self {
            state: OrderedMutex::new(ranks::SERVER_LANES, LaneState {
                queues: [VecDeque::new(), VecDeque::new()],
                open: true,
            }),
            cv: OrderedCondvar::new(),
            depth: [interactive_depth, batch_depth],
        }
    }

    fn push(&self, lane: usize, job: Job) -> std::result::Result<(), PushError> {
        let mut st = self.state.lock();
        if !st.open {
            return Err(PushError::Closed);
        }
        if st.queues[lane].len() >= self.depth[lane] {
            return Err(PushError::Full);
        }
        st.queues[lane].push_back(job);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop: interactive lane first, then batch; `None` once the
    /// lanes are closed AND drained (accepted work is always finished).
    fn pop(&self) -> Option<Job> {
        let mut st = self.state.lock();
        loop {
            for q in st.queues.iter_mut() {
                if let Some(job) = q.pop_front() {
                    return Some(job);
                }
            }
            if !st.open {
                return None;
            }
            st = self.cv.wait(st);
        }
    }

    fn close(&self) {
        self.state.lock().open = false;
        self.cv.notify_all();
    }
}

/// The query service.
pub struct Service {
    lanes: Arc<Lanes>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// The fabric-wide semantic query cache every worker shares.
    pub cache: Arc<QueryCache>,
    /// The memory fabric the workers query — kept for memory-pressure
    /// gauges in [`Service::snapshot`].
    fabric: Arc<MemoryFabric>,
    /// The ONE process-wide scoring pool every worker's engine fans its
    /// scatter-gather scoring out to — kept for the utilization gauges
    /// in [`Service::snapshot`].
    pool: Arc<ScorePool>,
    /// Per-stage span capture + slow-query log (DESIGN.md
    /// §Observability): the wire gateway serves its rings through the
    /// `trace` / `metrics_text` envelopes.
    pub tracer: Arc<Tracer>,
    next_id: AtomicU64,
}

impl Service {
    /// Start `cfg.server.workers` workers over the shared memory fabric.
    /// Every worker's engine shares the one process-wide backend, and all
    /// workers share one semantic query cache sized from `cfg.api`.
    pub fn start(cfg: &VenusConfig, fabric: Arc<MemoryFabric>, seed: u64) -> Result<Self> {
        let be = backend::shared_default()?;
        let (interactive_depth, batch_depth) = cfg.lane_depths();
        let lanes = Arc::new(Lanes::new(interactive_depth, batch_depth));
        let metrics = Arc::new(Metrics::default());
        let cache = Arc::new(QueryCache::from_config(&cfg.api));
        // build every engine BEFORE spawning any thread: a fallible step
        // after the first spawn would strand already-started workers on
        // the lane condvar with no Service to close it
        // ONE scoring pool shared by every worker's engine: a per-worker
        // pool would oversubscribe cores `workers`-fold under load
        let pool = Arc::new(ScorePool::new(cfg.server.resolved_score_workers()));
        let tracer = Arc::new(Tracer::new(&cfg.obs));
        let mut engines = Vec::new();
        for w in 0..cfg.server.workers {
            engines.push(
                QueryEngine::new(
                    EmbedEngine::new(Arc::clone(&be), cfg.ingest.aux_models)?,
                    Arc::clone(&fabric),
                    cfg.retrieval.clone(),
                    seed ^ ((w as u64) << 8),
                )
                .with_pool(Arc::clone(&pool)),
            );
        }
        let mut workers = Vec::new();
        for (w, engine) in engines.into_iter().enumerate() {
            let lanes2 = Arc::clone(&lanes);
            let met = Arc::clone(&metrics);
            let cache2 = Arc::clone(&cache);
            let tracer2 = Arc::clone(&tracer);
            let link = Link::new(cfg.net.clone());
            let vlm = VlmClient::new(cfg.cloud.clone(), seed ^ 0xf00d ^ w as u64);
            let fps = cfg.api.fps;
            workers.push(std::thread::spawn(move || {
                worker_loop(engine, lanes2, met, tracer2, link, vlm, cache2, fps)
            }));
        }
        Ok(Self {
            lanes,
            workers,
            metrics,
            cache,
            fabric,
            pool,
            tracer,
            next_id: AtomicU64::new(0),
        })
    }

    /// Live metrics snapshot, including the fabric's memory-pressure
    /// gauges (hot/cold tier residency, evictions, cold-hit rate) and
    /// the scoring pool's utilization + hot/cold time split.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = self.metrics.snapshot();
        snap.memory = Some(self.fabric.tier_stats());
        let g = self.pool.gauges();
        snap.scoring = Some(ScorePoolSnapshot {
            workers: g.workers,
            queue_depth: g.queue_depth,
            in_flight: g.in_flight,
            tasks_total: g.tasks_total,
            helped_total: g.helped_total,
            batches_total: g.batches_total,
            hot_score_ms: g.hot_score_ms,
            cold_score_ms: g.cold_score_ms,
        });
        snap
    }

    /// Camera streams in the fabric this service queries (the wire
    /// handshake advertises it so clients can validate `One` scopes).
    pub fn n_streams(&self) -> usize {
        self.fabric.n_streams()
    }

    /// Submit a typed request; returns a receiver for the structured
    /// response, or the typed reason admission turned it away.
    pub fn submit_request(
        &self,
        request: QueryRequest,
    ) -> std::result::Result<Receiver<Result<QueryResponse, ApiError>>, ApiError> {
        let lane = request.priority;
        // mint the trace before enqueueing so span offsets (queue wait
        // included) are measured from a birth instant that precedes them
        let trace = self.tracer.mint("query", &request.text);
        let (reply_tx, reply_rx) = sync_channel(1);
        let now = Instant::now();
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            deadline: request.deadline.map(|d| now + d),
            request,
            enqueued: now,
            reply: reply_tx,
            trace,
        };
        match self.lanes.push(lane.index(), job) {
            Ok(()) => {
                self.metrics.on_accepted(lane);
                Ok(reply_rx)
            }
            Err(PushError::Full) => {
                self.metrics.on_rejected(lane);
                Err(ApiError::Rejected { lane })
            }
            Err(PushError::Closed) => {
                self.metrics.on_shutdown_race();
                Err(ApiError::Shutdown)
            }
        }
    }

    /// Blocking convenience: submit a typed request and wait.
    pub fn call(&self, request: QueryRequest) -> std::result::Result<QueryResponse, ApiError> {
        let rx = self.submit_request(request)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(ApiError::Shutdown),
        }
    }

    /// Drain and stop all workers; returns the final metrics snapshot
    /// (memory-pressure gauges included).  Accepted work is always
    /// finished (or deadline-shed) before the workers exit.
    pub fn shutdown(mut self) -> Snapshot {
        self.close_and_join();
        self.snapshot()
    }

    fn close_and_join(&mut self) {
        self.lanes.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Dropping the service without an explicit [`Service::shutdown`] (early
/// return, error path, test teardown) must not strand the worker threads
/// blocked on the lane condvar — the old `SyncSender`-based queue got
/// this for free from channel disconnection, so the lanes must too.
impl Drop for Service {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn worker_loop(
    mut engine: QueryEngine,
    lanes: Arc<Lanes>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
    link: Link,
    vlm: VlmClient,
    cache: Arc<QueryCache>,
    fps: f64,
) {
    while let Some(mut job) = lanes.pop() {
        let lane = job.request.priority;
        metrics.on_dequeued(lane);
        // deadline-aware shedding: a query that aged out in the queue is
        // answered with the typed error instead of burning edge compute
        // (its trace context, if any, is dropped unfinished)
        if let Some(deadline) = job.deadline {
            if Instant::now() > deadline {
                metrics.on_deadline_shed(lane);
                let _ = job.reply.send(Err(ApiError::DeadlineExceeded));
                continue;
            }
        }
        let queue_wait = job.enqueued.elapsed();
        let queue_wait_s = queue_wait.as_secs_f64();
        let mut trace = job.trace.take();
        if let Some(tc) = trace.as_mut() {
            tc.record(stage::QUEUE_WAIT, job.enqueued, queue_wait);
        }
        let result = engine.retrieve_request_traced(
            &job.request.text,
            job.request.scope,
            job.request.mode,
            job.request.budget,
            Some(cache.as_ref()),
            trace.as_mut(),
        );
        match result {
            Ok((outcome, cache_status)) => {
                let n = outcome.selection.frames.len();
                let upload_s = link.round_trip_s(Payload::Frames(n));
                let vlm_s = vlm.infer_latency_s(n, job.request.approx_tokens());
                // publish the trace BEFORE replying, so the submitting
                // client can fetch its own span tree the moment it holds
                // the response
                let trace_id = trace.map(|mut tc| {
                    // upload + VLM latencies are modeled (the simulated
                    // link/VLM compute them instantly): place their spans
                    // after the measured edge stages at the simulated
                    // durations, keeping the span tree non-overlapping
                    // and its top-level sum tracking the reported total
                    let edge_end_us = tc.started().elapsed().as_micros() as u64;
                    let upload_us = (upload_s * 1e6) as u64;
                    tc.record_at(stage::UPLOAD, edge_end_us, upload_us, &[("frames", n as f64)]);
                    tc.record_at(stage::VLM, edge_end_us + upload_us, (vlm_s * 1e6) as u64, &[]);
                    let total = queue_wait_s + outcome.timings.total_s() + upload_s + vlm_s;
                    tracer.finish(tc, Duration::from_secs_f64(total))
                });
                let response = build_response(
                    job.id,
                    lane,
                    cache_status,
                    &outcome,
                    fps,
                    queue_wait_s,
                    upload_s,
                    vlm_s,
                    trace_id,
                );
                metrics.on_completed(
                    lane,
                    queue_wait_s,
                    outcome.timings.total_s(),
                    response.total_s(),
                    n,
                );
                let _ = job.reply.send(Ok(response));
            }
            Err(e) => {
                if let Some(tc) = trace {
                    let elapsed = tc.started().elapsed();
                    tracer.finish(tc, elapsed);
                }
                metrics.on_failed();
                let _ = job.reply.send(Err(ApiError::Engine(format!("{e:#}"))));
            }
        }
    }
}

/// Assemble the wire response from an edge outcome: evidence entries
/// carry the fabric-global frame id, its wall-clock position in the
/// stream (`idx / fps`), and the Eq. 4–5 score that drew it.
#[allow(clippy::too_many_arguments)]
fn build_response(
    id: u64,
    priority: Priority,
    cache: crate::api::cache::CacheStatus,
    outcome: &QueryOutcome,
    fps: f64,
    queue_wait_s: f64,
    upload_s: f64,
    vlm_s: f64,
    trace_id: Option<TraceId>,
) -> QueryResponse {
    let evidence = outcome
        .selection
        .frames
        .iter()
        .enumerate()
        .map(|(i, &frame)| Evidence {
            frame,
            time_s: frame.idx as f64 / fps,
            score: outcome.frame_scores.get(i).copied().unwrap_or(0.0),
        })
        .collect();
    QueryResponse {
        id,
        priority,
        cache,
        evidence,
        draws: outcome.draws,
        queue_wait_s,
        edge: outcome.timings,
        upload_s,
        vlm_s,
        trace_id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe_job(id: u64, priority: Priority) -> (Job, Receiver<Result<QueryResponse, ApiError>>) {
        let (tx, rx) = sync_channel(1);
        let job = Job {
            id,
            request: QueryRequest::new(format!("probe {id}")).priority(priority),
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
            trace: None,
        };
        (job, rx)
    }

    #[test]
    fn lanes_pop_interactive_before_batch_fifo_within() {
        let lanes = Lanes::new(4, 4);
        let mut rxs = Vec::new();
        for (id, p) in [
            (0, Priority::Batch),
            (1, Priority::Batch),
            (2, Priority::Interactive),
            (3, Priority::Interactive),
        ] {
            let (job, rx) = probe_job(id, p);
            lanes.push(p.index(), job).ok().unwrap();
            rxs.push(rx);
        }
        let order: Vec<u64> = (0..4).map(|_| lanes.pop().unwrap().id).collect();
        assert_eq!(order, vec![2, 3, 0, 1], "interactive first, FIFO within lanes");
        drop(rxs);
    }

    #[test]
    fn lanes_reject_independently_when_full() {
        let lanes = Lanes::new(1, 2);
        let (j, _r1) = probe_job(0, Priority::Interactive);
        assert!(lanes.push(0, j).is_ok());
        let (j, _r2) = probe_job(1, Priority::Interactive);
        assert!(matches!(lanes.push(0, j), Err(PushError::Full)));
        // the batch lane still has room
        let (j, _r3) = probe_job(2, Priority::Batch);
        assert!(lanes.push(1, j).is_ok());
    }

    #[test]
    fn closed_lanes_drain_then_end() {
        let lanes = Lanes::new(4, 4);
        let (j, _rx) = probe_job(7, Priority::Batch);
        lanes.push(1, j).ok().unwrap();
        lanes.close();
        let (j, _rx2) = probe_job(8, Priority::Interactive);
        assert!(matches!(lanes.push(0, j), Err(PushError::Closed)));
        // accepted work is still handed out after close...
        assert_eq!(lanes.pop().unwrap().id, 7);
        // ...and only then does pop signal drain-complete
        assert!(lanes.pop().is_none());
    }

    #[test]
    fn typed_entries_share_the_service_cache_and_drain_queue_gauges() {
        // submit_request and call against a live (empty-fabric) service:
        // typed responses, one shared query cache, and queue-depth gauges
        // back at zero once everything drained
        let cfg = VenusConfig::default();
        let d = EmbedEngine::default_backend(false).unwrap().d_embed();
        let raws: Vec<Box<dyn crate::memory::RawStore>> =
            vec![Box::new(crate::memory::InMemoryRaw::new(8))];
        let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d, raws).unwrap());
        let service = Service::start(&cfg, fabric, 3).unwrap();

        let resp = service
            .submit_request(QueryRequest::new("hello there"))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert!(resp.evidence.is_empty(), "empty fabric yields empty evidence");
        let resp2 = service.call(QueryRequest::new("hello there")).unwrap();
        assert!(resp2.cache.is_hit(), "both entries share the service's query cache");
        let resp3 = service
            .call(QueryRequest::new("hello there").scope(crate::memory::StreamScope::All))
            .unwrap();
        assert!(resp3.cache.is_hit());

        let snap = service.shutdown();
        assert_eq!(snap.completed(), 3);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.queued(), 0, "drained lanes report empty gauges");
        let sc = snap.scoring.expect("service snapshots carry pool gauges");
        assert!(sc.workers >= 1);
        assert_eq!(sc.queue_depth, 0, "idle pool reports an empty queue");
    }

    #[test]
    fn default_sampling_traces_every_query_and_publishes_before_reply() {
        let cfg = VenusConfig::default();
        let d = EmbedEngine::default_backend(false).unwrap().d_embed();
        let raws: Vec<Box<dyn crate::memory::RawStore>> =
            vec![Box::new(crate::memory::InMemoryRaw::new(8))];
        let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d, raws).unwrap());
        let service = Service::start(&cfg, fabric, 3).unwrap();

        let resp = service.call(QueryRequest::new("trace me please")).unwrap();
        let id = resp.trace_id.expect("default obs config samples 1/1");
        // finish() runs before the reply is sent: the trace must already
        // be in the ring by the time the caller holds the response
        let tr = service.tracer.lookup(id).expect("trace published before reply");
        assert_eq!(tr.kind, "query");
        for st in [stage::QUEUE_WAIT, stage::EMBED, stage::SCORE, stage::VLM] {
            assert!(tr.span(st).is_some(), "missing span {st:?}");
        }
        // cold path over an empty fabric still reports a coherent total:
        // top-level stages fit inside it
        assert!(tr.stage_sum_us() <= tr.total_us.max(1) * 2);
        service.shutdown();
    }
}
