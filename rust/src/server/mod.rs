//! Online serving loop: multi-worker query service with admission
//! control, per-query latency accounting, and a metrics registry.
//!
//! Each worker thread owns its own query engine with its own embed
//! backend (AOT backends compile per-thread; PJRT handles are not
//! shared).  Queries enter through a bounded queue — when it is full,
//! `submit` rejects immediately (admission control) instead of building
//! unbounded backlog.  The memory hierarchy is behind an `RwLock`, so
//! worker threads score/select concurrently (queries are read-only).

pub mod metrics;

pub use metrics::{Metrics, Snapshot};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::backend;
use crate::cloud::VlmClient;
use crate::config::VenusConfig;
use crate::coordinator::query::{QueryEngine, QueryOutcome};
use crate::embed::EmbedEngine;
use crate::memory::Hierarchy;
use crate::net::{Link, Payload};

/// A completed query with its latency accounting.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: u64,
    pub outcome: QueryOutcome,
    pub queue_wait_s: f64,
    pub upload_s: f64,
    pub vlm_s: f64,
}

impl QueryResult {
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.outcome.timings.total_s() + self.upload_s + self.vlm_s
    }
}

struct Job {
    id: u64,
    text: String,
    enqueued: Instant,
    reply: SyncSender<Result<QueryResult>>,
}

/// Wrapper moving a possibly-PJRT-owning engine into its worker thread
/// (see `ingest::pipeline::SendEngine` for the safety argument).
struct SendEngine(QueryEngine);
unsafe impl Send for SendEngine {}

/// The query service.
pub struct Service {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Service {
    /// Start `cfg.server.workers` workers over a shared memory hierarchy.
    pub fn start(cfg: &VenusConfig, memory: Arc<RwLock<Hierarchy>>, seed: u64) -> Result<Self> {
        let (tx, rx) = sync_channel::<Job>(cfg.server.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for w in 0..cfg.server.workers {
            let engine = QueryEngine::new(
                EmbedEngine::new(backend::load_default()?, cfg.ingest.aux_models)?,
                Arc::clone(&memory),
                cfg.retrieval.clone(),
                seed ^ (w as u64) << 8,
            );
            let send_engine = SendEngine(engine);
            let rx2 = Arc::clone(&rx);
            let met = Arc::clone(&metrics);
            let link = Link::new(cfg.net.clone());
            let vlm = VlmClient::new(cfg.cloud.clone(), seed ^ 0xf00d ^ w as u64);
            workers.push(std::thread::spawn(move || {
                worker_loop(send_engine, rx2, met, link, vlm)
            }));
        }
        Ok(Self { tx: Some(tx), workers, metrics, next_id: AtomicU64::new(0) })
    }

    /// Submit a query; returns a receiver for the result, or `None` if the
    /// queue is full (admission-controlled rejection).
    pub fn submit(&self, text: &str) -> Option<Receiver<Result<QueryResult>>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            text: text.to_string(),
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.as_ref().unwrap().try_send(job) {
            Ok(()) => {
                self.metrics.on_accepted();
                Some(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.on_rejected();
                None
            }
            Err(TrySendError::Disconnected(_)) => None,
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn query(&self, text: &str) -> Result<QueryResult> {
        let rx = self
            .submit(text)
            .ok_or_else(|| anyhow::anyhow!("queue full: query rejected"))?;
        rx.recv()?
    }

    /// Drain and stop all workers; returns the final metrics snapshot.
    pub fn shutdown(mut self) -> Snapshot {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

fn worker_loop(
    engine: SendEngine,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    link: Link,
    vlm: VlmClient,
) {
    let mut engine = engine.0;
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // channel closed: drain complete
            }
        };
        let queue_wait_s = job.enqueued.elapsed().as_secs_f64();
        match engine.retrieve(&job.text) {
            Ok(outcome) => {
                let n = outcome.selection.frames.len();
                let upload_s = link.round_trip_s(Payload::Frames(n));
                let vlm_s =
                    vlm.infer_latency_s(n, job.text.split_whitespace().count() * 2);
                let result = QueryResult {
                    id: job.id,
                    outcome,
                    queue_wait_s,
                    upload_s,
                    vlm_s,
                };
                metrics.on_completed(
                    queue_wait_s,
                    result.outcome.timings.total_s(),
                    result.total_s(),
                    n,
                );
                let _ = job.reply.send(Ok(result));
            }
            Err(e) => {
                metrics.on_failed();
                let _ = job.reply.send(Err(e));
            }
        }
    }
}
