//! Online serving loop: multi-worker query service with admission
//! control, per-query latency accounting, and a metrics registry.
//!
//! Worker threads each own a cheap query-engine front-end over the ONE
//! process-shared embed backend (`backend::shared_default`) and the
//! shared memory fabric — backends are never rebuilt per worker.  Queries
//! enter through a bounded queue with an explicit stream scope; when the
//! queue is full, `submit` rejects immediately (admission control)
//! instead of building unbounded backlog, and a submission that races
//! service shutdown reports [`SubmitError::Shutdown`] — a distinct
//! condition, so admission-control stats stay clean.  Shards are behind
//! per-stream `RwLock`s, so workers score/select concurrently (queries
//! are read-only) and only contend with the ingestion writer of the
//! stream(s) they actually touch.

pub mod metrics;

pub use metrics::{Metrics, Snapshot};

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::backend;
use crate::cloud::VlmClient;
use crate::config::VenusConfig;
use crate::coordinator::query::{QueryEngine, QueryOutcome};
use crate::embed::EmbedEngine;
use crate::memory::{MemoryFabric, StreamScope};
use crate::net::{Link, Payload};

/// A completed query with its latency accounting.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: u64,
    pub outcome: QueryOutcome,
    pub queue_wait_s: f64,
    pub upload_s: f64,
    pub vlm_s: f64,
}

impl QueryResult {
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.outcome.timings.total_s() + self.upload_s + self.vlm_s
    }
}

/// Why a submission did not enter the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full: admission control turned the query away.  Retry later
    /// (or shed load) — the service is healthy, just saturated.
    Rejected,
    /// The worker channel is disconnected: the service is shutting down.
    /// Not an admission-control event; don't retry.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Rejected => write!(f, "queue full: query rejected"),
            SubmitError::Shutdown => write!(f, "service shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    id: u64,
    text: String,
    scope: StreamScope,
    enqueued: Instant,
    reply: SyncSender<Result<QueryResult>>,
}

/// The query service.
pub struct Service {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Service {
    /// Start `cfg.server.workers` workers over the shared memory fabric.
    /// Every worker's engine shares the one process-wide backend.
    pub fn start(cfg: &VenusConfig, fabric: Arc<MemoryFabric>, seed: u64) -> Result<Self> {
        let be = backend::shared_default()?;
        let (tx, rx) = sync_channel::<Job>(cfg.server.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for w in 0..cfg.server.workers {
            let engine = QueryEngine::new(
                EmbedEngine::new(Arc::clone(&be), cfg.ingest.aux_models)?,
                Arc::clone(&fabric),
                cfg.retrieval.clone(),
                seed ^ ((w as u64) << 8),
            );
            let rx2 = Arc::clone(&rx);
            let met = Arc::clone(&metrics);
            let link = Link::new(cfg.net.clone());
            let vlm = VlmClient::new(cfg.cloud.clone(), seed ^ 0xf00d ^ w as u64);
            workers.push(std::thread::spawn(move || {
                worker_loop(engine, rx2, met, link, vlm)
            }));
        }
        Ok(Self { tx: Some(tx), workers, metrics, next_id: AtomicU64::new(0) })
    }

    /// Submit an all-streams query; returns a receiver for the result, or
    /// the reason the submission didn't enter the queue.
    pub fn submit(&self, text: &str) -> Result<Receiver<Result<QueryResult>>, SubmitError> {
        self.submit_scoped(text, StreamScope::All)
    }

    /// Submit a query with an explicit stream scope.
    pub fn submit_scoped(
        &self,
        text: &str,
        scope: StreamScope,
    ) -> Result<Receiver<Result<QueryResult>>, SubmitError> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            text: text.to_string(),
            scope,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.as_ref().unwrap().try_send(job) {
            Ok(()) => {
                self.metrics.on_accepted();
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.on_rejected();
                Err(SubmitError::Rejected)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.metrics.on_shutdown_race();
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn query(&self, text: &str) -> Result<QueryResult> {
        let rx = self.submit(text).map_err(anyhow::Error::new)?;
        rx.recv()?
    }

    /// Drain and stop all workers; returns the final metrics snapshot.
    pub fn shutdown(mut self) -> Snapshot {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

fn worker_loop(
    mut engine: QueryEngine,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    link: Link,
    vlm: VlmClient,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // channel closed: drain complete
            }
        };
        let queue_wait_s = job.enqueued.elapsed().as_secs_f64();
        match engine.retrieve_scoped(&job.text, job.scope) {
            Ok(outcome) => {
                let n = outcome.selection.frames.len();
                let upload_s = link.round_trip_s(Payload::Frames(n));
                let vlm_s =
                    vlm.infer_latency_s(n, job.text.split_whitespace().count() * 2);
                let result = QueryResult {
                    id: job.id,
                    outcome,
                    queue_wait_s,
                    upload_s,
                    vlm_s,
                };
                metrics.on_completed(
                    queue_wait_s,
                    result.outcome.timings.total_s(),
                    result.total_s(),
                    n,
                );
                let _ = job.reply.send(Ok(result));
            }
            Err(e) => {
                metrics.on_failed();
                let _ = job.reply.send(Err(e));
            }
        }
    }
}
