//! Std-only base64 (RFC 4648, standard alphabet, `=` padding) plus
//! little-endian `f32` slab helpers.
//!
//! The ingest wire carries raw frame pixels as base64 text inside JSON
//! envelopes.  Frames must survive the trip *bit-exactly* — scene
//! segmentation and clustering decisions hang on float comparisons, and
//! the reconnect test asserts selection-bit-identical recovery — so the
//! f32 helpers serialize the IEEE-754 bytes verbatim (little-endian)
//! rather than going through decimal formatting.

use anyhow::{bail, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Decode table: 255 = invalid, 254 = padding (`=`).
const fn build_rev() -> [u8; 256] {
    let mut rev = [255u8; 256];
    let mut i = 0;
    while i < 64 {
        rev[ALPHABET[i] as usize] = i as u8;
        i += 1;
    }
    rev[b'=' as usize] = 254;
    rev
}

const REV: [u8; 256] = build_rev();

/// Encode bytes as standard base64 with padding.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    let mut chunks = bytes.chunks_exact(3);
    for c in &mut chunks {
        let n = ((c[0] as u32) << 16) | ((c[1] as u32) << 8) | c[2] as u32;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(ALPHABET[(n >> 6) as usize & 63] as char);
        out.push(ALPHABET[n as usize & 63] as char);
    }
    match *chunks.remainder() {
        [a] => {
            let n = (a as u32) << 16;
            out.push(ALPHABET[(n >> 18) as usize & 63] as char);
            out.push(ALPHABET[(n >> 12) as usize & 63] as char);
            out.push_str("==");
        }
        [a, b] => {
            let n = ((a as u32) << 16) | ((b as u32) << 8);
            out.push(ALPHABET[(n >> 18) as usize & 63] as char);
            out.push(ALPHABET[(n >> 12) as usize & 63] as char);
            out.push(ALPHABET[(n >> 6) as usize & 63] as char);
            out.push('=');
        }
        _ => {}
    }
    out
}

/// Decode standard base64 (padding required, no whitespace).  Wire input
/// is attacker-shaped: every malformed form is a typed error, never a
/// panic.
pub fn decode(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 4 != 0 {
        bail!("base64 length {} is not a multiple of 4", b.len());
    }
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    for (i, quad) in b.chunks_exact(4).enumerate() {
        let last = (i + 1) * 4 == b.len();
        let mut vals = [0u32; 4];
        let mut pad = 0usize;
        for (j, &ch) in quad.iter().enumerate() {
            match REV[ch as usize] {
                255 => bail!("invalid base64 byte 0x{ch:02x} at offset {}", i * 4 + j),
                254 => {
                    // padding: only in the final quad, only the tail,
                    // at most two
                    if !last || j < 2 {
                        bail!("misplaced base64 padding at offset {}", i * 4 + j);
                    }
                    pad += 1;
                }
                v => {
                    if pad > 0 {
                        bail!("base64 data after padding at offset {}", i * 4 + j);
                    }
                    vals[j] = v as u32;
                }
            }
        }
        let n = (vals[0] << 18) | (vals[1] << 12) | (vals[2] << 6) | vals[3];
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

/// Encode an `f32` slice as base64 over its little-endian bytes.
pub fn encode_f32s(v: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(v.len() * 4);
    for x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    encode(&bytes)
}

/// Decode base64 back to `f32`s; bit-exact inverse of [`encode_f32s`].
pub fn decode_f32s(s: &str) -> Result<Vec<f32>> {
    let bytes = decode(s)?;
    if bytes.len() % 4 != 0 {
        bail!("f32 payload is {} bytes, not a multiple of 4", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for q in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([q[0], q[1], q[2], q[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, b64) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), b64);
            assert_eq!(decode(b64).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn round_trips_every_byte_value() {
        let all: Vec<u8> = (0..=255u8).collect();
        for end in [0, 1, 2, 3, 17, 255, 256] {
            let slice = &all[..end];
            assert_eq!(decode(&encode(slice)).unwrap(), slice);
        }
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "A",        // not a multiple of 4
            "AAA=extra", // length ok but data after the padded quad
            "AA=A",     // data after padding inside a quad
            "=AAA",     // padding in the head
            "AAAA\n",   // whitespace is not tolerated
            "AA!A",     // alphabet violation
            "====",     // all padding
        ] {
            assert!(decode(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn f32s_bit_exact_including_specials() {
        let v = vec![
            0.0f32,
            -0.0,
            1.5,
            -3.25e-7,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
        ];
        let back = decode_f32s(&encode_f32s(&v)).unwrap();
        assert_eq!(back.len(), v.len());
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit pattern drifted");
        }
    }

    #[test]
    fn f32s_reject_ragged_payloads() {
        // 3 bytes decoded: not a whole f32
        assert!(decode_f32s(&encode(&[1, 2, 3])).is_err());
    }
}
