//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Warm-up + timed iterations with mean / p50 / p99 reporting, plus a
//! one-line `section` API the per-table benches use to print paper-style
//! output.  Timings use `std::time::Instant` (monotonic).
//!
//! With `BENCH_JSON_DIR=<dir>` set, every `Bench` additionally appends
//! its results to `<dir>/BENCH_<target>.json` (one JSON object per
//! line, `<target>` = the bench binary's name) so CI can persist a
//! machine-readable perf trajectory next to the printed tables.

use std::hint::black_box;
use std::io::Write;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{fmt_duration, Samples};

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>10}  p99 {:>10}  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p99),
            self.iters
        )
    }

    /// One flat JSON object (seconds for all timings) — the unit CI's
    /// `BENCH_*.json` artifacts are made of.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_s".to_string(), Json::Num(self.mean));
        m.insert("p50_s".to_string(), Json::Num(self.p50));
        m.insert("p99_s".to_string(), Json::Num(self.p99));
        m.insert("min_s".to_string(), Json::Num(self.min));
        Json::Obj(m)
    }
}

/// The bench target's name, recovered from the binary path (cargo names
/// bench binaries `<target>-<metadata hash>`).
fn bench_target_name() -> String {
    let stem = std::env::args()
        .next()
        .and_then(|p| {
            std::path::Path::new(&p).file_stem().map(|s| s.to_string_lossy().into_owned())
        })
        .unwrap_or_default();
    match stem.rsplit_once('-') {
        Some((base, hash))
            if !base.is_empty() && hash.len() == 16 && hash.bytes().all(|b| b.is_ascii_hexdigit()) =>
        {
            base.to_string()
        }
        _ if stem.is_empty() => "bench".to_string(),
        _ => stem,
    }
}

/// Micro-bench runner.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: Duration, measure: Duration) -> Self {
        Self { warmup, measure, ..Self::default() }
    }

    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self::new(Duration::from_millis(50), Duration::from_millis(300))
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples = Samples::default();
        let start = Instant::now();
        let mut iters = 0;
        while start.elapsed() < self.measure && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push_duration(t0.elapsed());
            iters += 1;
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean: samples.mean(),
            p50: samples.p50(),
            p99: samples.p99(),
            min: samples.min(),
        };
        println!("  {}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    fn persist_json(&self) -> std::io::Result<()> {
        let Ok(dir) = std::env::var("BENCH_JSON_DIR") else { return Ok(()) };
        if dir.is_empty() || self.results.is_empty() {
            return Ok(());
        }
        std::fs::create_dir_all(&dir)?;
        let path =
            std::path::Path::new(&dir).join(format!("BENCH_{}.json", bench_target_name()));
        // append: one bench target often builds several Bench runners
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        for r in &self.results {
            writeln!(f, "{}", r.to_json())?;
        }
        Ok(())
    }
}

/// Append one headline scalar (a throughput, a tail latency, a freshness
/// bound...) to this target's `BENCH_<target>.json` line stream — the
/// non-[`Bench`] counterpart for end-to-end benches whose numbers are
/// aggregates of a whole run rather than per-iteration timings.  Same
/// contract as [`Bench`]: a no-op unless `BENCH_JSON_DIR` is set.
pub fn persist_metric(name: &str, value: f64, unit: &str) {
    let Ok(dir) = std::env::var("BENCH_JSON_DIR") else { return };
    if dir.is_empty() {
        return;
    }
    let write = || -> std::io::Result<()> {
        std::fs::create_dir_all(&dir)?;
        let path =
            std::path::Path::new(&dir).join(format!("BENCH_{}.json", bench_target_name()));
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.to_string()));
        m.insert("value".to_string(), Json::Num(value));
        m.insert("unit".to_string(), Json::Str(unit.to_string()));
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{}", Json::Obj(m))
    };
    if let Err(e) = write() {
        eprintln!("warning: could not persist bench metric {name}: {e}");
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        if let Err(e) = self.persist_json() {
            eprintln!("warning: could not persist bench JSON: {e}");
        }
    }
}

/// Print a bench/eval section header (paper table/figure ids).
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Print an indented note line.
pub fn note(text: &str) {
    println!("    {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new(Duration::from_millis(1), Duration::from_millis(20));
        let r = b.run("noop-ish", || (0..100).sum::<u64>());
        assert!(r.iters > 10);
        assert!(r.mean >= 0.0);
        assert!(r.p99 >= r.p50);
    }

    #[test]
    fn result_json_round_trips() {
        let r = BenchResult {
            name: "fused \"embed\" b8".to_string(),
            iters: 42,
            mean: 1.5e-3,
            p50: 1.25e-3,
            p99: 4.0e-3,
            min: 1.0e-3,
        };
        let v = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "fused \"embed\" b8");
        assert_eq!(v.get("iters").unwrap().as_usize().unwrap(), 42);
        assert!((v.get("p99_s").unwrap().as_f64().unwrap() - 4.0e-3).abs() < 1e-12);
    }

    #[test]
    fn target_name_strips_cargo_metadata_hash() {
        // (exercises the parsing helper; the real name comes from argv)
        assert!(!bench_target_name().is_empty());
    }
}
