//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Warm-up + timed iterations with mean / p50 / p99 reporting, plus a
//! one-line `section` API the per-table benches use to print paper-style
//! output.  Timings use `std::time::Instant` (monotonic).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::{fmt_duration, Samples};

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>10}  p99 {:>10}  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p99),
            self.iters
        )
    }
}

/// Micro-bench runner.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(warmup: Duration, measure: Duration) -> Self {
        Self { warmup, measure, ..Self::default() }
    }

    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self::new(Duration::from_millis(50), Duration::from_millis(300))
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples = Samples::default();
        let start = Instant::now();
        let mut iters = 0;
        while start.elapsed() < self.measure && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push_duration(t0.elapsed());
            iters += 1;
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean: samples.mean(),
            p50: samples.p50(),
            p99: samples.p99(),
            min: samples.min(),
        };
        println!("  {}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Print a bench/eval section header (paper table/figure ids).
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Print an indented note line.
pub fn note(text: &str) {
    println!("    {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new(Duration::from_millis(1), Duration::from_millis(20));
        let r = b.run("noop-ish", || (0..100).sum::<u64>());
        assert!(r.iters > 10);
        assert!(r.mean >= 0.0);
        assert!(r.p99 >= r.p50);
    }
}
