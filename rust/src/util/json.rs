//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Parses the artifact `manifest.json` and serializes metrics/eval reports.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (sufficient for our ASCII manifests, asserted in tests).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // --- typed accessors ---

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (wanted key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a boolean: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// `[1,2,3]` -> Vec<usize>, for shape lists.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // re-sync to utf-8 boundary
                    let start = self.i - 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

/// Writer: escape + compact/indented serialization.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn shape_accessor() {
        let v = Json::parse("[8, 64, 64, 3]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![8, 64, 64, 3]);
        assert!(Json::parse("[1.5]").unwrap().as_shape().is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"k":[1,2.5,"s\n",true,null]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}
