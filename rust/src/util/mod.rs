//! Zero-dependency utility substrates: deterministic RNG, streaming
//! statistics, a JSON parser (for the artifact manifest), and the in-tree
//! micro-benchmark harness used by `cargo bench` (criterion is not
//! available offline).

pub mod b64;
pub mod bench;
pub mod json;
pub mod rng;
pub mod scorer;
pub mod simd;
pub mod stats;
pub mod sync;

/// L2-normalize a vector in place; returns the original norm.
pub fn l2_normalize(v: &mut [f32]) -> f32 {
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        let inv = 1.0 / norm;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    norm
}

/// Dot product over `chunks_exact(8)` with lane-wise accumulators: the
/// fixed-size chunks eliminate bounds checks and break the sequential FP
/// dependence chain, letting the autovectorizer emit packed FMAs.
/// (§Perf note: indexed manual unrolling regressed 2.6× here — bounds
/// checks defeat vectorization; chunked slices are the fast formulation.)
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let (ca, ra) = a.split_at(a.len() & !7);
    let (cb, rb) = b.split_at(b.len() & !7);
    for (xa, xb) in ca.chunks_exact(8).zip(cb.chunks_exact(8)) {
        for l in 0..8 {
            lanes[l] += xa[l] * xb[l];
        }
    }
    let mut acc: f32 = lanes.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        acc += x * y;
    }
    acc
}

/// Softmax with temperature over `scores[..n]`, writing probabilities into
/// `out` (which must be the same length).  Pure-Rust mirror of the fused
/// Pallas similarity kernel's epilogue; used for index sizes that exceed
/// the AOT-compiled kernel's padded capacity.
pub fn softmax_temp(scores: &[f32], tau: f32, out: &mut [f32]) {
    assert_eq!(scores.len(), out.len());
    if scores.is_empty() {
        return;
    }
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for (o, s) in out.iter_mut().zip(scores.iter()) {
        let e = ((s - m) / tau).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        let n = l2_normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn l2_normalize_zero_vector_untouched() {
        let mut v = vec![0.0; 4];
        let n = l2_normalize(&mut v);
        assert_eq!(n, 0.0);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let scores = [0.1f32, 0.9, 0.5];
        let mut p = [0.0f32; 3];
        softmax_temp(&scores, 0.5, &mut p);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[1] > p[2] && p[2] > p[0]);
    }

    #[test]
    fn softmax_low_temp_concentrates() {
        let scores = [0.1f32, 0.9, 0.5];
        let mut p = [0.0f32; 3];
        softmax_temp(&scores, 0.01, &mut p);
        assert!(p[1] > 0.999);
    }

    #[test]
    fn dot_matches_manual() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }
}
