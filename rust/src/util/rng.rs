//! Deterministic PCG64 (DXSM) pseudo-random generator.
//!
//! Every stochastic component in Venus — the synthetic video generator, the
//! workload generator, multinomial retrieval sampling, the VLM answer model
//! — draws from an explicitly-seeded [`Pcg64`], so every experiment and
//! every property test is bit-reproducible.  (The `rand` crate is not
//! available offline; this is the 2019 O'Neill PCG64-DXSM variant.)

/// PCG64 DXSM generator (128-bit state, 64-bit output).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.  Distinct streams
    /// from the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator for a named sub-component; the label is
    /// hashed (FNV-1a) into the stream id so call sites stay readable.
    pub fn fork(&mut self, label: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Self::new(self.next_u64(), h)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }

    /// Next raw 64-bit value (DXSM output permutation).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample needs positive mass");
        let mut t = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::seeded(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_respected() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Pcg64::seeded(5);
        let w = [0.05f32, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > 8_500, "{counts:?}");
        assert!(counts[0] > 100 && counts[2] > 100, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_labels_independent() {
        let mut root = Pcg64::seeded(1);
        let mut a = root.clone().fork("alpha");
        let mut b = root.fork("beta");
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
