//! The process-wide **scoring pool**: a fixed set of worker threads that
//! fan one query's scoring work out as row-disjoint tasks (DESIGN.md
//! §Parallel-Query).
//!
//! Determinism is structural, not scheduled: every task writes into a
//! **pre-sliced disjoint region** of the merged score buffer that the
//! submitter carved up before submission, and each task runs the exact
//! per-row kernels of the serial path (`dot_batch*`), so the concatenated
//! output is bit-identical to serial scoring no matter how the pool
//! interleaves tasks.  Parallelism exists only *across* rows/segments —
//! never inside a row's FP accumulation order.
//!
//! Scheduling is **helping**: `run_batch` enqueues its tasks and then the
//! submitting thread drains the shared queue alongside the workers until
//! its batch's completion latch hits zero.  That gives three properties
//! at once: `score_workers = 1` degrades gracefully toward inline serial
//! execution (the submitter does the work itself), concurrent submitters
//! can never deadlock waiting on a fully-busy pool (the waiter is itself
//! a worker), and there is no idle hand-off latency for tiny batches.
//!
//! Lock discipline (vlint R2-clean — all locks are ordered wrappers):
//! the submitter holds its scoped shard read guards (ranks `SHARD_BASE+i`)
//! while touching the pool, so both pool locks rank above the shard band:
//! [`ranks::SCORE_POOL_QUEUE`] for the task queue and
//! [`ranks::SCORE_POOL_LATCH`] for the per-batch latch/error slot.  Tasks
//! themselves may acquire the cold block cache
//! ([`ranks::COLD_BLOCK_CACHE`]), which ranks above both.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::anyhow;

use crate::util::sync::{ranks, OrderedCondvar, OrderedMutex};
use crate::Result;

/// One unit of scoring work: a closure that fills its pre-assigned
/// disjoint slice of the merged score buffer (or prefetches a cold block)
/// and reports I/O failures.  Borrows are allowed (`'a`): `run_batch`
/// blocks until every task of the batch has fully executed, so the
/// borrows outlive all use.
pub type ScoreTask<'a> = Box<dyn FnOnce() -> Result<()> + Send + 'a>;

type StaticTask = Box<dyn FnOnce() -> Result<()> + Send + 'static>;

/// Completion latch + first-error slot for one `run_batch` call.
struct BatchState {
    /// Tasks not yet finished.  Decremented with `Release` after the
    /// task closure has been consumed, so a submitter observing zero
    /// (`Acquire`) happens-after every write the tasks performed.
    remaining: AtomicUsize,
    /// First task error (I/O failure or caught panic), if any.
    fail: OrderedMutex<Option<anyhow::Error>>,
    cv: OrderedCondvar,
}

struct QueueItem {
    task: StaticTask,
    batch: Arc<BatchState>,
}

struct Queue {
    items: VecDeque<QueueItem>,
    shutdown: bool,
}

/// State shared between the pool handle and its worker threads.
struct Shared {
    queue: OrderedMutex<Queue>,
    cv: OrderedCondvar,
    /// Tasks currently executing (workers + helping submitters).
    in_flight: AtomicUsize,
    tasks_total: AtomicU64,
    /// Tasks executed by helping submitters rather than pool workers.
    helped_total: AtomicU64,
    batches_total: AtomicU64,
    /// Cumulative nanoseconds spent in hot-index scoring tasks.
    hot_ns: AtomicU64,
    /// Cumulative nanoseconds spent in cold-segment scoring tasks.
    cold_ns: AtomicU64,
}

/// Instantaneous + cumulative pool gauges, consumed by
/// `server::metrics::ScorePoolSnapshot`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolGauges {
    pub workers: u64,
    /// Tasks queued but not yet claimed, at snapshot time.
    pub queue_depth: u64,
    /// Tasks executing right now (workers + helping submitters).
    pub in_flight: u64,
    pub tasks_total: u64,
    pub helped_total: u64,
    pub batches_total: u64,
    /// Cumulative milliseconds in hot-index scoring tasks.
    pub hot_score_ms: f64,
    /// Cumulative milliseconds in cold-segment scoring tasks.
    pub cold_score_ms: f64,
}

/// Fixed-size scoring thread pool.  One per process (the server builds a
/// single pool shared by every query worker); benches and tests build
/// their own.
pub struct ScorePool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl ScorePool {
    /// Spawn a pool with `workers` threads (clamped to at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: OrderedMutex::new(
                ranks::SCORE_POOL_QUEUE,
                Queue { items: VecDeque::new(), shutdown: false },
            ),
            cv: OrderedCondvar::new(),
            in_flight: AtomicUsize::new(0),
            tasks_total: AtomicU64::new(0),
            helped_total: AtomicU64::new(0),
            batches_total: AtomicU64::new(0),
            hot_ns: AtomicU64::new(0),
            cold_ns: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("venus-score-{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawn scoring worker")
            })
            .collect();
        Self { shared, workers, handles }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every task of one query's scoring batch to completion,
    /// returning the first task error (if any).  Blocks — helping drain
    /// the queue — until the whole batch has executed, which is what
    /// makes lending stack borrows to the tasks sound.
    pub fn run_batch(&self, tasks: Vec<ScoreTask<'_>>) -> Result<()> {
        let n = tasks.len();
        if n == 0 {
            return Ok(());
        }
        self.shared.batches_total.fetch_add(1, Ordering::Relaxed);
        let batch = Arc::new(BatchState {
            remaining: AtomicUsize::new(n),
            fail: OrderedMutex::new(ranks::SCORE_POOL_LATCH, None),
            cv: OrderedCondvar::new(),
        });
        {
            let mut q = self.shared.queue.lock();
            for task in tasks {
                // SAFETY: lifetime erasure only.  `run_batch` does not
                // return until `remaining` reaches zero, and an executor
                // decrements `remaining` (Release) only after the FnOnce
                // has been consumed — so every `'a` borrow captured by
                // the task strictly outlives its last use, and the
                // Acquire load below orders the submitter after all of
                // the tasks' writes.
                let task: StaticTask =
                    unsafe { std::mem::transmute::<ScoreTask<'_>, StaticTask>(task) };
                q.items.push_back(QueueItem { task, batch: Arc::clone(&batch) });
            }
        }
        self.shared.cv.notify_all();
        while batch.remaining.load(Ordering::Acquire) > 0 {
            let item = self.shared.queue.lock().items.pop_front();
            match item {
                Some(item) => {
                    // Help: drain any queued task (not necessarily ours)
                    // instead of sleeping.
                    self.shared.helped_total.fetch_add(1, Ordering::Relaxed);
                    execute(&self.shared, item);
                }
                None => {
                    let g = batch.fail.lock();
                    if batch.remaining.load(Ordering::Acquire) == 0 {
                        break;
                    }
                    // Re-checked under the latch mutex, so the executor's
                    // locked notify cannot slip between check and wait;
                    // the timeout is belt-and-braces.
                    let _ = batch.cv.wait_timeout(g, Duration::from_millis(2));
                }
            }
        }
        let err = batch.fail.lock().take();
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Attribute `ns` nanoseconds to hot-index scoring (called from
    /// inside hot tasks).
    pub fn note_hot_ns(&self, ns: u64) {
        self.shared.hot_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Attribute `ns` nanoseconds to cold-segment scoring (called from
    /// inside cold tasks).
    pub fn note_cold_ns(&self, ns: u64) {
        self.shared.cold_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Snapshot the pool gauges (queue depth is instantaneous).
    pub fn gauges(&self) -> PoolGauges {
        let queue_depth = self.shared.queue.lock().items.len() as u64;
        PoolGauges {
            workers: self.workers as u64,
            queue_depth,
            in_flight: self.shared.in_flight.load(Ordering::Relaxed) as u64,
            tasks_total: self.shared.tasks_total.load(Ordering::Relaxed),
            helped_total: self.shared.helped_total.load(Ordering::Relaxed),
            batches_total: self.shared.batches_total.load(Ordering::Relaxed),
            hot_score_ms: self.shared.hot_ns.load(Ordering::Relaxed) as f64 / 1e6,
            cold_score_ms: self.shared.cold_ns.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

impl Drop for ScorePool {
    fn drop(&mut self) {
        self.shared.queue.lock().shutdown = true;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ScorePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScorePool").field("workers", &self.workers).finish()
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let item = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(item) = q.items.pop_front() {
                    break Some(item);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.cv.wait(q);
            }
        };
        match item {
            Some(item) => execute(shared, item),
            None => return,
        }
    }
}

/// Run one task with no locks held, then record its outcome on the batch.
/// A panicking task (unreachable for the in-tree tasks, which funnel
/// errors through `Result`) is converted into a batch error rather than
/// killing the worker or hanging the submitter.
fn execute(shared: &Shared, item: QueueItem) {
    let QueueItem { task, batch } = item;
    shared.in_flight.fetch_add(1, Ordering::Relaxed);
    shared.tasks_total.fetch_add(1, Ordering::Relaxed);
    let outcome = catch_unwind(AssertUnwindSafe(task));
    shared.in_flight.fetch_sub(1, Ordering::Relaxed);
    let err = match outcome {
        Ok(Ok(())) => None,
        Ok(Err(e)) => Some(e),
        Err(_) => Some(anyhow!("scoring task panicked")),
    };
    if let Some(e) = err {
        let mut slot = batch.fail.lock();
        if slot.is_none() {
            *slot = Some(e);
        }
    }
    if batch.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Last task out: notify under the latch mutex so a submitter
        // between its remaining-check and wait cannot miss the wake.
        let _g = batch.fail.lock();
        batch.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn disjoint_slices_fill_completely() {
        let pool = ScorePool::new(4);
        let mut buf = vec![0.0f32; 64];
        let mut tasks: Vec<ScoreTask<'_>> = Vec::new();
        let mut rest = buf.as_mut_slice();
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = rest.len().min(7);
            let (chunk, r) = rest.split_at_mut(take);
            rest = r;
            let start = base;
            base += take;
            tasks.push(Box::new(move || {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = (start + i) as f32;
                }
                Ok(())
            }));
        }
        pool.run_batch(tasks).expect("batch succeeds");
        for (i, x) in buf.iter().enumerate() {
            assert_eq!(*x, i as f32);
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = ScorePool::new(2);
        pool.run_batch(Vec::new()).expect("empty batch");
        assert_eq!(pool.gauges().batches_total, 0);
    }

    #[test]
    fn first_error_is_surfaced() {
        let pool = ScorePool::new(2);
        let ran = AtomicU32::new(0);
        let tasks: Vec<ScoreTask<'_>> = (0..8)
            .map(|i| {
                let ran = &ran;
                Box::new(move || {
                    ran.fetch_add(1, Ordering::Relaxed);
                    if i == 3 {
                        anyhow::bail!("segment {i} checksum mismatch");
                    }
                    Ok(())
                }) as ScoreTask<'_>
            })
            .collect();
        let err = pool.run_batch(tasks).expect_err("task 3 fails the batch");
        assert!(err.to_string().contains("checksum mismatch"));
        // every task still ran to completion (the latch drained)
        assert_eq!(ran.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn panicking_task_fails_the_batch_without_hanging() {
        let pool = ScorePool::new(2);
        let tasks: Vec<ScoreTask<'_>> = vec![
            Box::new(|| Ok(())),
            Box::new(|| panic!("injected")),
            Box::new(|| Ok(())),
        ];
        let err = pool.run_batch(tasks).expect_err("panic becomes an error");
        assert!(err.to_string().contains("panicked"));
        // the pool survives and keeps executing later batches
        pool.run_batch(vec![Box::new(|| Ok(())) as ScoreTask<'_>]).expect("pool alive");
    }

    #[test]
    fn concurrent_submitters_make_progress_on_one_worker() {
        // With a single worker, every submitter must help drain or this
        // would starve; four threads × many tasks each all complete.
        let pool = std::sync::Arc::new(ScorePool::new(1));
        let total = AtomicU32::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = std::sync::Arc::clone(&pool);
                let total = &total;
                s.spawn(move || {
                    for _ in 0..10 {
                        let tasks: Vec<ScoreTask<'_>> = (0..16)
                            .map(|_| {
                                Box::new(move || {
                                    total.fetch_add(1, Ordering::Relaxed);
                                    Ok(())
                                }) as ScoreTask<'_>
                            })
                            .collect();
                        pool.run_batch(tasks).expect("batch");
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 16);
        let g = pool.gauges();
        assert_eq!(g.tasks_total, 4 * 10 * 16);
        assert_eq!(g.batches_total, 40);
        assert_eq!(g.queue_depth, 0);
        assert_eq!(g.in_flight, 0);
    }

    #[test]
    fn gauges_track_timing_notes() {
        let pool = ScorePool::new(1);
        pool.note_hot_ns(2_000_000);
        pool.note_cold_ns(500_000);
        let g = pool.gauges();
        assert!((g.hot_score_ms - 2.0).abs() < 1e-9);
        assert!((g.cold_score_ms - 0.5).abs() < 1e-9);
        assert_eq!(g.workers, 1);
    }

    #[test]
    fn submitter_may_hold_a_shard_guard_while_running_a_batch() {
        // Mirrors the query path's lock discipline: shard read guard
        // (rank SHARD_BASE) held across run_batch.  Debug builds assert
        // rank order, so this test fails loudly on an inversion.
        use crate::util::sync::{ranks, OrderedRwLock};
        let pool = ScorePool::new(2);
        let shard = OrderedRwLock::new(ranks::shard(3), vec![1.0f32; 8]);
        let g = shard.read();
        let data: &[f32] = &g;
        let mut out = vec![0.0f32; 8];
        let tasks: Vec<ScoreTask<'_>> = vec![Box::new(|| {
            out.copy_from_slice(data);
            Ok(())
        })];
        pool.run_batch(tasks).expect("batch under shard guard");
        assert_eq!(out, vec![1.0f32; 8]);
    }
}
