//! Chunked, autovectorizable batch scoring kernels.
//!
//! Every O(N·d) scan in the system — the flat index, the IVF exact scan,
//! and the cold tier's segment scans — funnels through these two kernels
//! instead of calling the scalar [`crate::util::dot`] once per row:
//!
//! * [`dot_batch`] scores a query against a contiguous row-major f32
//!   block.  Rows are processed four at a time so the query chunk is
//!   loaded once per four rows, and each row keeps the exact 8-lane
//!   accumulation order of the scalar `dot` — the result is **bit
//!   identical** per row (the tiered memory's exactness contract rides
//!   on this; see the `batch_matches_scalar_bit_for_bit` property test).
//! * [`dot_batch_sq8`] is the asymmetric SQ8 kernel: the query stays
//!   f32 while rows are u8 codes, fused dequantize-and-accumulate with
//!   the per-dimension affine map folded into the query (see
//!   `DESIGN.md` §Quantization-and-ANN for the algebra).
//!
//! Same autovectorization idiom as `util::dot`: fixed-width
//! `chunks_exact` slices eliminate bounds checks and the lane arrays
//! break the sequential FP dependence chain, so the compiler emits
//! packed FMAs (manual indexed unrolling regressed 2.6× — §Perf).

/// Rows scored per inner block: enough independent accumulator state to
/// hide FMA latency without spilling the 4×8 lane array out of registers.
const ROW_BLOCK: usize = 4;

/// Score `q` against every `d`-wide row of the contiguous block `rows`,
/// appending one score per row to `out` in row order.  Each row's value
/// is bit-identical to `crate::util::dot(q, row)`.  Thin wrapper over
/// [`dot_batch_into`] — the slice form the parallel scoring pool writes
/// through — so both entry points share one per-row op order.
pub fn dot_batch(q: &[f32], rows: &[f32], d: usize, out: &mut Vec<f32>) {
    let start = out.len();
    out.resize(start + rows.len() / d.max(1), 0.0);
    dot_batch_into(q, rows, d, &mut out[start..]);
}

/// Slice form of [`dot_batch`]: write one score per row into the
/// pre-sized `out` (`out.len()` must equal the row count).  Used by the
/// scoring pool, whose tasks fill disjoint regions of one merged buffer.
pub fn dot_batch_into(q: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    debug_assert!(d > 0, "dot_batch: zero dimension");
    debug_assert_eq!(q.len(), d, "dot_batch: query length != d");
    debug_assert_eq!(rows.len() % d, 0, "dot_batch: ragged row block");
    debug_assert_eq!(out.len(), rows.len() / d.max(1), "dot_batch: mis-sized out slice");
    let mut w = 0usize;
    let split = d & !7;
    let (qc, qr) = q.split_at(split);
    let mut quads = rows.chunks_exact(ROW_BLOCK * d);
    for quad in &mut quads {
        let (r0, rest) = quad.split_at(d);
        let (r1, rest) = rest.split_at(d);
        let (r2, r3) = rest.split_at(d);
        let (c0, t0) = r0.split_at(split);
        let (c1, t1) = r1.split_at(split);
        let (c2, t2) = r2.split_at(split);
        let (c3, t3) = r3.split_at(split);
        let mut lanes = [[0.0f32; 8]; ROW_BLOCK];
        for ((((qx, x0), x1), x2), x3) in qc
            .chunks_exact(8)
            .zip(c0.chunks_exact(8))
            .zip(c1.chunks_exact(8))
            .zip(c2.chunks_exact(8))
            .zip(c3.chunks_exact(8))
        {
            for l in 0..8 {
                lanes[0][l] += qx[l] * x0[l];
                lanes[1][l] += qx[l] * x1[l];
                lanes[2][l] += qx[l] * x2[l];
                lanes[3][l] += qx[l] * x3[l];
            }
        }
        let mut acc = [
            lanes[0].iter().sum::<f32>(),
            lanes[1].iter().sum::<f32>(),
            lanes[2].iter().sum::<f32>(),
            lanes[3].iter().sum::<f32>(),
        ];
        for ((((x, y0), y1), y2), y3) in qr.iter().zip(t0).zip(t1).zip(t2).zip(t3) {
            acc[0] += x * y0;
            acc[1] += x * y1;
            acc[2] += x * y2;
            acc[3] += x * y3;
        }
        out[w..w + ROW_BLOCK].copy_from_slice(&acc);
        w += ROW_BLOCK;
    }
    for row in quads.remainder().chunks_exact(d) {
        out[w] = crate::util::dot(q, row);
        w += 1;
    }
}

/// Asymmetric SQ8 scan: score `d`-wide u8 rows against a *pre-weighted*
/// f32 query, appending `offset + Σⱼ w[j]·codes[row·d + j]` per row.
///
/// The caller folds the per-dimension affine dequantization into the
/// query once per (query, segment) pair: with stored rows
/// `x̂[j] = min[j] + step[j]·code[j]`, the asymmetric dot
/// `Σ q[j]·x̂[j]` equals `dot(q, min) + Σ (q[j]·step[j])·code[j]` — so
/// `offset = dot(q, min)` and `w[j] = q[j]·step[j]`, and the inner loop
/// is a single fused u8→f32 multiply-accumulate per element.
pub fn dot_batch_sq8(w: &[f32], codes: &[u8], d: usize, offset: f32, out: &mut Vec<f32>) {
    let start = out.len();
    out.resize(start + codes.len() / d.max(1), 0.0);
    dot_batch_sq8_into(w, codes, d, offset, &mut out[start..]);
}

/// Slice form of [`dot_batch_sq8`] (see [`dot_batch_into`] for why the
/// pool needs it): writes into the pre-sized `out` instead of appending.
pub fn dot_batch_sq8_into(w: &[f32], codes: &[u8], d: usize, offset: f32, out: &mut [f32]) {
    debug_assert!(d > 0, "dot_batch_sq8: zero dimension");
    debug_assert_eq!(w.len(), d, "dot_batch_sq8: weight length != d");
    debug_assert_eq!(codes.len() % d, 0, "dot_batch_sq8: ragged code block");
    debug_assert_eq!(out.len(), codes.len() / d.max(1), "dot_batch_sq8: mis-sized out slice");
    let mut wi = 0usize;
    let split = d & !7;
    let (wc, wr) = w.split_at(split);
    let mut quads = codes.chunks_exact(ROW_BLOCK * d);
    for quad in &mut quads {
        let (r0, rest) = quad.split_at(d);
        let (r1, rest) = rest.split_at(d);
        let (r2, r3) = rest.split_at(d);
        let (c0, t0) = r0.split_at(split);
        let (c1, t1) = r1.split_at(split);
        let (c2, t2) = r2.split_at(split);
        let (c3, t3) = r3.split_at(split);
        let mut lanes = [[0.0f32; 8]; ROW_BLOCK];
        for ((((wx, x0), x1), x2), x3) in wc
            .chunks_exact(8)
            .zip(c0.chunks_exact(8))
            .zip(c1.chunks_exact(8))
            .zip(c2.chunks_exact(8))
            .zip(c3.chunks_exact(8))
        {
            for l in 0..8 {
                lanes[0][l] += wx[l] * x0[l] as f32;
                lanes[1][l] += wx[l] * x1[l] as f32;
                lanes[2][l] += wx[l] * x2[l] as f32;
                lanes[3][l] += wx[l] * x3[l] as f32;
            }
        }
        let mut acc = [
            offset + lanes[0].iter().sum::<f32>(),
            offset + lanes[1].iter().sum::<f32>(),
            offset + lanes[2].iter().sum::<f32>(),
            offset + lanes[3].iter().sum::<f32>(),
        ];
        for ((((x, y0), y1), y2), y3) in wr.iter().zip(t0).zip(t1).zip(t2).zip(t3) {
            acc[0] += x * *y0 as f32;
            acc[1] += x * *y1 as f32;
            acc[2] += x * *y2 as f32;
            acc[3] += x * *y3 as f32;
        }
        out[wi..wi + ROW_BLOCK].copy_from_slice(&acc);
        wi += ROW_BLOCK;
    }
    for row in quads.remainder().chunks_exact(d) {
        let mut lanes = [0.0f32; 8];
        let (rc, rt) = row.split_at(split);
        for (wx, x) in wc.chunks_exact(8).zip(rc.chunks_exact(8)) {
            for l in 0..8 {
                lanes[l] += wx[l] * x[l] as f32;
            }
        }
        let mut acc = offset + lanes.iter().sum::<f32>();
        for (x, y) in wr.iter().zip(rt) {
            acc += x * *y as f32;
        }
        out[wi] = acc;
        wi += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randoms(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        // NaN-free bounded randoms (normal deviates)
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Property (exactness contract): the chunked batch kernel matches
    /// the scalar reference bit for bit — across odd lengths, block
    /// remainders, and the production d=512.
    #[test]
    fn batch_matches_scalar_bit_for_bit() {
        let mut rng = Pcg64::seeded(0xd07);
        for d in [1usize, 3, 7, 8, 9, 15, 16, 17, 31, 64, 127, 512] {
            for n in [1usize, 2, 3, 4, 5, 7, 8, 13] {
                let q = randoms(&mut rng, d);
                let rows = randoms(&mut rng, n * d);
                let mut got = Vec::new();
                dot_batch(&q, &rows, d, &mut got);
                assert_eq!(got.len(), n);
                for (i, row) in rows.chunks_exact(d).enumerate() {
                    let want = crate::util::dot(&q, row);
                    assert_eq!(
                        got[i].to_bits(),
                        want.to_bits(),
                        "d={d} n={n} row {i}: batch {} != scalar {want}",
                        got[i]
                    );
                }
            }
        }
    }

    /// Property: the SQ8 asymmetric kernel reconstructs the f32 dot
    /// within the derived quantization error bound
    /// `Σⱼ |q[j]|·step[j]/2` (half a quantization step per dimension)
    /// plus float-accumulation slack.
    #[test]
    fn sq8_within_derived_error_bound() {
        let mut rng = Pcg64::seeded(0x5a8);
        for d in [3usize, 8, 17, 64, 512] {
            for n in [1usize, 4, 9] {
                let q = randoms(&mut rng, d);
                let rows = randoms(&mut rng, n * d);
                // per-dimension affine quantization, as the sealer does
                let mut mins = vec![f32::INFINITY; d];
                let mut maxs = vec![f32::NEG_INFINITY; d];
                for row in rows.chunks_exact(d) {
                    for j in 0..d {
                        mins[j] = mins[j].min(row[j]);
                        maxs[j] = maxs[j].max(row[j]);
                    }
                }
                let steps: Vec<f32> =
                    mins.iter().zip(&maxs).map(|(lo, hi)| (hi - lo) / 255.0).collect();
                let codes: Vec<u8> = rows
                    .chunks_exact(d)
                    .flat_map(|row| {
                        row.iter().enumerate().map(|(j, &x)| {
                            if steps[j] > 0.0 {
                                ((x - mins[j]) / steps[j]).round().clamp(0.0, 255.0) as u8
                            } else {
                                0
                            }
                        })
                    })
                    .collect();
                let offset = crate::util::dot(&q, &mins);
                let w: Vec<f32> = q.iter().zip(&steps).map(|(x, s)| x * s).collect();
                let mut got = Vec::new();
                dot_batch_sq8(&w, &codes, d, offset, &mut got);
                assert_eq!(got.len(), n);
                let bound: f32 = q
                    .iter()
                    .zip(&steps)
                    .map(|(x, s)| (x * s / 2.0).abs())
                    .sum::<f32>()
                    + 1e-4 * d as f32;
                for (i, row) in rows.chunks_exact(d).enumerate() {
                    let exact = crate::util::dot(&q, row);
                    let err = (got[i] - exact).abs();
                    assert!(
                        err <= bound,
                        "d={d} row {i}: sq8 err {err} exceeds bound {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_block_scores_nothing() {
        let mut out = vec![9.0f32];
        dot_batch(&[1.0, 2.0], &[], 2, &mut out);
        assert_eq!(out, vec![9.0], "appends nothing for an empty block");
        dot_batch_sq8(&[1.0, 2.0], &[], 2, 0.0, &mut out);
        assert_eq!(out, vec![9.0]);
    }

    #[test]
    fn sq8_zero_step_dimension_uses_offset_only() {
        // a constant dimension quantizes to step 0: the value lives
        // entirely in the offset term
        let w = [0.0f32, 0.5]; // q[0]*step[0] = 0
        let codes = [7u8, 4, 9, 2];
        let mut out = Vec::new();
        dot_batch_sq8(&w, &codes, 2, 1.25, &mut out);
        assert_eq!(out, vec![1.25 + 2.0, 1.25 + 1.0]);
    }
}
