//! Streaming statistics and latency summaries.
//!
//! Used by the metrics registry, the evaluation harness, and the in-tree
//! bench harness: Welford mean/variance, exact percentiles over recorded
//! samples, and human-readable duration formatting.

use std::fmt;
use std::time::Duration;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Sample recorder with exact percentiles (sorts on query).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.xs.push(d.as_secs_f64());
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile (nearest-rank), q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }
}

/// Fixed-boundary histogram (for latency distributions in metrics output).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// `bounds` are the upper edges of each bucket; a final overflow bucket
    /// is appended automatically.
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len() + 1;
        Self { bounds, counts: vec![0; n], total: 0 }
    }

    /// Exponential buckets: `start * factor^i` for `count` buckets.
    pub fn exponential(start: f64, factor: f64, count: usize) -> Self {
        let mut bounds = Vec::with_capacity(count);
        let mut b = start;
        for _ in 0..count {
            bounds.push(b);
            b *= factor;
        }
        Self::new(bounds)
    }

    pub fn observe(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.bounds
            .iter()
            .cloned()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().cloned())
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (bound, count) in self.buckets() {
            acc += count;
            if acc >= target {
                return bound;
            }
        }
        f64::INFINITY
    }
}

/// Pretty duration: "4.83 s", "12.4 ms", "380 µs", "2.1 min".
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 90.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{:.2} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Human-readable byte count (tier gauges, memory-growth output).
pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else {
        format!("{bytes} B")
    }
}

/// Simple fixed-width table printer for bench/eval output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |f: &mut fmt::Formatter<'_>, cells: &[String]| -> fmt::Result {
            write!(f, "|")?;
            for (w, c) in widths.iter().zip(cells) {
                write!(f, " {c:<w$} |")?;
            }
            writeln!(f)
        };
        line(f, &self.header)?;
        write!(f, "|")?;
        for w in &widths {
            write!(f, "{}|", "-".repeat(w + 2))?;
        }
        writeln!(f)?;
        for row in &self.rows {
            line(f, row)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 51.0); // nearest-rank: round(0.5·99) = index 50
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn histogram_buckets_and_quantile() {
        let mut h = Histogram::exponential(1.0, 2.0, 4); // 1,2,4,8,inf
        for x in [0.5, 1.5, 3.0, 6.0, 100.0] {
            h.observe(x);
        }
        assert_eq!(h.total(), 5);
        let counts: Vec<u64> = h.buckets().map(|(_, c)| c).collect();
        assert_eq!(counts, vec![1, 1, 1, 1, 1]);
        assert_eq!(h.quantile(0.2), 1.0);
        assert!(h.quantile(1.0).is_infinite());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(120.0), "2.0 min");
        assert_eq!(fmt_duration(4.83), "4.83 s");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(fmt_duration(0.0124), "12.40 ms");
        assert_eq!(fmt_duration(3.8e-4), "380.0 µs");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "22"]);
        let s = t.to_string();
        assert!(s.contains("| a | b  |"));
        assert!(s.contains("| 1 | 22 |"));
    }
}
