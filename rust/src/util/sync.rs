//! Rank-ordered, poison-recovering lock layer — the one place in the
//! tree that is allowed to touch `std::sync::{Mutex, RwLock}` (enforced
//! by `tools/vlint` rule R2).
//!
//! Two failure classes motivated this layer (DESIGN.md §Static-Analysis):
//!
//! * **Poisoning cascades.**  `std` locks poison on a panic while held,
//!   and every later `.lock().unwrap()` then panics too — one crashed
//!   wire handler used to take the gateway's stats, the shutdown drain,
//!   and eventually the process down with it.  These wrappers recover
//!   the inner value instead (`PoisonError::into_inner`): all guarded
//!   state here is either a plain counter/gauge, a registry whose
//!   entries are reaped by owner threads, or protocol state that is
//!   re-validated by its consumer, so observing a mid-panic value is
//!   strictly better than cascading the panic.
//!
//! * **Undocumented lock order.**  The serving path nests up to three
//!   lock layers (query cache → fabric shards → metrics/stats).  Every
//!   lock in the tree now declares a numeric **rank** from the registry
//!   in [`ranks`], and debug builds keep a per-thread stack of held
//!   ranks: acquiring a lock whose rank is not strictly greater than
//!   every rank already held panics immediately with both ranks named.
//!   Inversions therefore fail deterministically in the tier-1 test run
//!   (`[profile.dev]` keeps `debug_assertions` on) instead of deadlocking
//!   once a year in production.  Release builds compile the bookkeeping
//!   out entirely.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// The fabric-wide lock-rank registry.  Locks may only be acquired in
/// strictly ascending rank order; the table below IS the documented
/// acquisition order (mirrored in DESIGN.md §Static-Analysis).  Gaps are
/// deliberate — future locks slot in without renumbering.
pub mod ranks {
    /// Serving admission lanes (`server::Lanes`) — a leaf: held only
    /// across push/pop bookkeeping and condvar waits.
    pub const SERVER_LANES: u32 = 10;
    /// Gateway shutdown signal flag (`net::wire::gateway`).
    pub const WIRE_SHUTDOWN_SIGNAL: u32 = 11;
    /// Shared embed-pool job receiver (`ingest::pool`).
    pub const POOL_QUEUE: u32 = 12;
    /// Process-wide shared-backend once-cache (`backend::shared_default`).
    pub const BACKEND_SHARED: u32 = 13;
    /// Load-generator tally merge (`net::wire::loadgen`).
    pub const LOADGEN_TALLIES: u32 = 15;
    /// Ingest-hub stream registry (`net::wire::ingest`) — below the
    /// shard band: a registry probe precedes every per-stream lock.
    pub const WIRE_INGEST_STREAMS: u32 = 16;
    /// One ingest stream's session (`net::wire::ingest`) — below the
    /// shard band: the session lock is held across `Pipeline::push_frame`,
    /// which takes its shard's write guard (rank `SHARD_BASE + i`).
    pub const WIRE_INGEST_SESSION: u32 = 17;
    /// Semantic query cache (`api::cache`) — below the shard band: a
    /// cache probe must never be attempted while scoring holds shards.
    pub const QUERY_CACHE: u32 = 100;
    /// First fabric shard.  Shard `i` has rank `SHARD_BASE + i`, so the
    /// query path's "acquire scoped shards in ascending `StreamId`
    /// order" rule is exactly the ascending-rank rule.
    pub const SHARD_BASE: u32 = 200;
    /// Scoring-pool task queue (`util::scorer`) — above the shard band:
    /// the query path enqueues (and helps drain) scoring tasks while
    /// holding its scoped shard read guards.
    pub const SCORE_POOL_QUEUE: u32 = 900_000;
    /// Scoring-pool per-batch completion latch / first-error slot
    /// (`util::scorer`) — just above the queue: executors record
    /// completion after releasing the queue lock, and the submitter
    /// waits on it holding only shard guards.
    pub const SCORE_POOL_LATCH: u32 = 900_010;
    /// Cold-tier segment block cache (`memory::segment`) — above the
    /// shard band AND the scoring-pool locks: cold scoring runs under a
    /// shard read guard, possibly inside a pool task.
    pub const COLD_BLOCK_CACHE: u32 = 1_000_000;
    /// Durable raw-layer read-handle cache (`memory::storage::DiskRaw`)
    /// — above the shard band: frame fetches run under shard guards.
    pub const RAW_READ_CACHE: u32 = 1_000_010;
    /// PJRT compiled-executable cache (`runtime::pjrt`) — above the
    /// shard band: backend entry points may be invoked under a guard.
    pub const PJRT_EXEC_CACHE: u32 = 1_000_015;
    /// Per-stream ingest progress tracker (`ingest::pool`).
    pub const STREAM_PROGRESS: u32 = 1_000_020;
    /// Serving metrics (`server::metrics`) — the top band: counters are
    /// updated after all retrieval locks are released.
    pub const SERVER_METRICS: u32 = 2_000_000;
    /// Gateway wire counters (`net::wire::gateway::WireStats`).
    pub const WIRE_STATS: u32 = 2_000_010;
    /// Gateway live-connection registry.
    pub const WIRE_CONNS: u32 = 2_000_020;
    /// Gateway handler-thread join list.
    pub const WIRE_HANDLERS: u32 = 2_000_030;
    /// Central trace collector rings (`obs::Tracer`) — the very top:
    /// finished span trees are published after every other lock is
    /// released (workers finish a trace only once guards, metrics and
    /// wire locks are gone), and readers (the `trace` / `metrics_text`
    /// wire arms) take it with nothing else held.
    pub const OBS_TRACER: u32 = 2_000_040;

    /// Rank of fabric shard `index` (ascending `StreamId` order).  The
    /// fabric caps streams at `u16::MAX`, so the shard band never
    /// reaches [`COLD_BLOCK_CACHE`].
    pub fn shard(index: usize) -> u32 {
        SHARD_BASE + index as u32
    }
}

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks of every ordered lock this thread currently holds, in
    /// acquisition order.  A Vec, not a stack discipline: guards may be
    /// dropped out of acquisition order, so release removes the newest
    /// matching entry rather than popping.
    static HELD_RANKS: std::cell::RefCell<Vec<u32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

#[cfg(debug_assertions)]
fn acquire_rank(rank: u32) {
    HELD_RANKS.with(|cell| {
        let mut held = cell.borrow_mut();
        if let Some(&max) = held.iter().max() {
            assert!(
                rank > max,
                "lock-rank inversion: acquiring rank {rank} while holding rank {max} \
                 (held: {held:?}) — locks must be taken in strictly ascending rank \
                 order, see util::sync::ranks and DESIGN.md §Static-Analysis"
            );
        }
        held.push(rank);
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn acquire_rank(_rank: u32) {}

#[cfg(debug_assertions)]
fn release_rank(rank: u32) {
    HELD_RANKS.with(|cell| {
        let mut held = cell.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&r| r == rank) {
            held.remove(pos);
        }
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn release_rank(_rank: u32) {}

/// A `Mutex` with a declared lock rank and poison recovery.
///
/// `lock()` returns the guard directly (not a `Result`): a poisoned
/// inner mutex is recovered, never cascaded.  Debug builds assert the
/// per-thread rank order on every acquisition.
pub struct OrderedMutex<T> {
    rank: u32,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// `const` so ordered locks can back `static` once-caches.
    pub const fn new(rank: u32, value: T) -> Self {
        Self { rank, inner: Mutex::new(value) }
    }

    /// Acquire, recovering from poisoning.  Panics (debug builds only)
    /// if `self.rank` is not strictly above every rank this thread holds.
    pub fn lock(&self) -> OrderedMutexGuard<'_, T> {
        acquire_rank(self.rank);
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        OrderedMutexGuard { inner: Some(inner), rank: self.rank }
    }

    /// Consume the lock, recovering the value even if poisoned.
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex").field("rank", &self.rank).field("inner", &self.inner).finish()
    }
}

/// Guard for [`OrderedMutex`].  The inner guard sits in an `Option`
/// solely so [`OrderedCondvar`] can take it across a wait without
/// running this guard's rank release.
pub struct OrderedMutexGuard<'a, T> {
    inner: Option<MutexGuard<'a, T>>,
    rank: u32,
}

impl<T> Deref for OrderedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard consumed by a condvar wait")
    }
}

impl<T> DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard consumed by a condvar wait")
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            release_rank(self.rank);
        }
    }
}

/// An `RwLock` with a declared lock rank and poison recovery, mirroring
/// [`OrderedMutex`].  Reader/writer distinction is unchanged from `std`.
pub struct OrderedRwLock<T> {
    rank: u32,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    pub const fn new(rank: u32, value: T) -> Self {
        Self { rank, inner: RwLock::new(value) }
    }

    /// Shared acquire, recovering from poisoning.
    pub fn read(&self) -> OrderedReadGuard<'_, T> {
        acquire_rank(self.rank);
        let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
        OrderedReadGuard { inner, rank: self.rank }
    }

    /// Exclusive acquire, recovering from poisoning.
    pub fn write(&self) -> OrderedWriteGuard<'_, T> {
        acquire_rank(self.rank);
        let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
        OrderedWriteGuard { inner, rank: self.rank }
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedRwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedRwLock")
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Shared guard for [`OrderedRwLock`].
pub struct OrderedReadGuard<'a, T> {
    inner: RwLockReadGuard<'a, T>,
    rank: u32,
}

impl<T> Deref for OrderedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for OrderedReadGuard<'_, T> {
    fn drop(&mut self) {
        release_rank(self.rank);
    }
}

/// Exclusive guard for [`OrderedRwLock`].
pub struct OrderedWriteGuard<'a, T> {
    inner: RwLockWriteGuard<'a, T>,
    rank: u32,
}

impl<T> Deref for OrderedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for OrderedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for OrderedWriteGuard<'_, T> {
    fn drop(&mut self) {
        release_rank(self.rank);
    }
}

/// A `Condvar` that waits on [`OrderedMutex`] guards.
///
/// The waiter's rank stays registered for the whole wait: the thread is
/// blocked and cannot acquire anything anyway, and keeping it held means
/// the guard handed back after wake carries the same bookkeeping it went
/// to sleep with.  Poisoning during the wait is recovered like every
/// other acquisition in this module.
pub struct OrderedCondvar {
    cv: Condvar,
}

impl OrderedCondvar {
    pub const fn new() -> Self {
        Self { cv: Condvar::new() }
    }

    /// Block until notified; the re-acquired guard is handed back.
    pub fn wait<'a, T>(&self, mut guard: OrderedMutexGuard<'a, T>) -> OrderedMutexGuard<'a, T> {
        let rank = guard.rank;
        let inner = guard.inner.take().expect("guard consumed by a condvar wait");
        let inner = self.cv.wait(inner).unwrap_or_else(PoisonError::into_inner);
        OrderedMutexGuard { inner: Some(inner), rank }
    }

    /// Block until notified or `dur` elapses.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedMutexGuard<'a, T>, WaitTimeoutResult) {
        let rank = guard.rank;
        let inner = guard.inner.take().expect("guard consumed by a condvar wait");
        let (inner, timeout) = match self.cv.wait_timeout(inner, dur) {
            Ok(pair) => pair,
            Err(poisoned) => poisoned.into_inner(),
        };
        (OrderedMutexGuard { inner: Some(inner), rank }, timeout)
    }

    pub fn notify_one(&self) {
        self.cv.notify_one();
    }

    pub fn notify_all(&self) {
        self.cv.notify_all();
    }
}

impl Default for OrderedCondvar {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_guards_mutation() {
        let m = OrderedMutex::new(10, 0u64);
        *m.lock() += 41;
        *m.lock() += 1;
        assert_eq!(*m.lock(), 42);
        assert_eq!(m.rank(), 10);
        assert_eq!(m.into_inner(), 42);
    }

    #[test]
    fn rwlock_readers_and_writer() {
        let l = OrderedRwLock::new(200, vec![1, 2, 3]);
        {
            let a = l.read();
            assert_eq!(a.len(), 3);
        }
        l.write().push(4);
        assert_eq!(*l.read(), vec![1, 2, 3, 4]);
        assert_eq!(l.into_inner(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn poisoned_mutex_recovers_instead_of_cascading() {
        let m = Arc::new(OrderedMutex::new(10, 7u32));
        let m2 = Arc::clone(&m);
        let t = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("die while holding the lock");
        });
        assert!(t.join().is_err(), "the injected panic propagated");
        // the poisoned state is recovered, not re-panicked
        assert_eq!(*m.lock(), 7);
        *m.lock() = 8;
        assert_eq!(*m.lock(), 8);
    }

    #[test]
    fn poisoned_rwlock_and_into_inner_recover() {
        let l = Arc::new(OrderedRwLock::new(200, 3u32));
        let l2 = Arc::clone(&l);
        let t = std::thread::spawn(move || {
            let _g = l2.write();
            panic!("die while holding the write lock");
        });
        assert!(t.join().is_err());
        assert_eq!(*l.read(), 3);
        let l = Arc::try_unwrap(l).ok().expect("sole owner");
        assert_eq!(l.into_inner(), 3);
    }

    #[test]
    fn ascending_rank_acquisition_is_allowed() {
        let low = OrderedMutex::new(100, ());
        let shard = OrderedRwLock::new(ranks::shard(0), ());
        let high = OrderedMutex::new(ranks::SERVER_METRICS, ());
        let _a = low.lock();
        let _b = shard.read();
        let _c = high.lock();
    }

    #[test]
    fn out_of_order_drop_keeps_the_ledger_consistent() {
        let a = OrderedMutex::new(10, ());
        let b = OrderedMutex::new(20, ());
        let c = OrderedMutex::new(30, ());
        let ga = a.lock();
        let gb = b.lock();
        let gc = c.lock();
        // drop the middle guard first: release must remove rank 20, not
        // blindly pop rank 30
        drop(gb);
        drop(ga);
        drop(gc);
        // a fresh ascending chain still works
        let _ga = a.lock();
        let _gc = c.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn rank_inversion_panics_deterministically_in_debug() {
        let shard = OrderedRwLock::new(ranks::shard(1), ());
        let cache = OrderedMutex::new(ranks::QUERY_CACHE, ());
        let guard = shard.read();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // cache (100) under a shard guard (201): an inversion
            let _g = cache.lock();
        }));
        let err = result.expect_err("inversion must panic in debug builds");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap_or_default());
        assert!(msg.contains("lock-rank inversion"), "panic names the inversion: {msg}");
        drop(guard);
        // the failed acquisition left no stale held-rank entry behind
        let _g = cache.lock();
    }

    #[test]
    fn condvar_wakes_and_times_out() {
        let pair = Arc::new((OrderedMutex::new(ranks::STREAM_PROGRESS, false), OrderedCondvar::new()));
        // timeout path
        let (flag, cv) = (&pair.0, &pair.1);
        let (g, timeout) = cv.wait_timeout(flag.lock(), Duration::from_millis(5));
        assert!(timeout.timed_out());
        assert!(!*g);
        drop(g);
        // notify path
        let pair2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (flag, cv) = (&pair2.0, &pair2.1);
            *flag.lock() = true;
            cv.notify_all();
        });
        let (flag, cv) = (&pair.0, &pair.1);
        let mut g = flag.lock();
        while !*g {
            let (g2, _) = cv.wait_timeout(g, Duration::from_millis(50));
            g = g2;
        }
        t.join().expect("notifier thread");
    }

    #[test]
    fn const_new_backs_a_static() {
        static ONCE: OrderedMutex<Option<u32>> = OrderedMutex::new(ranks::BACKEND_SHARED, None);
        let mut slot = ONCE.lock();
        let v = *slot.get_or_insert(9);
        assert_eq!(v, 9);
    }
}
