//! RGB frame representation and pixel utilities.
//!
//! Frames are square `size × size × 3` f32 images in [0, 1], row-major,
//! channel-interleaved — exactly the layout the AOT image-tower artifacts
//! expect, so a frame batch can be memcpy'd into a PJRT literal.

/// A single video frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    size: usize,
    data: Vec<f32>,
}

impl Frame {
    /// Allocate a black frame.
    pub fn new(size: usize) -> Self {
        Self { size, data: vec![0.0; size * size * 3] }
    }

    /// Constant-color frame.
    pub fn filled(size: usize, rgb: [f32; 3]) -> Self {
        let mut f = Self::new(size);
        for px in f.data.chunks_exact_mut(3) {
            px.copy_from_slice(&rgb);
        }
        f
    }

    /// Wrap existing pixel data (must be `size·size·3` long).
    pub fn from_data(size: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), size * size * 3);
        Self { size, data }
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn rgb(&self, y: usize, x: usize) -> (f32, f32, f32) {
        let i = (y * self.size + x) * 3;
        (self.data[i], self.data[i + 1], self.data[i + 2])
    }

    #[inline]
    pub fn set_rgb(&mut self, y: usize, x: usize, rgb: [f32; 3]) {
        let i = (y * self.size + x) * 3;
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    /// Blend `rgb` into the pixel with weight `alpha`.
    #[inline]
    pub fn blend_rgb(&mut self, y: usize, x: usize, rgb: [f32; 3], alpha: f32) {
        let i = (y * self.size + x) * 3;
        for c in 0..3 {
            self.data[i + c] = alpha * rgb[c] + (1.0 - alpha) * self.data[i + c];
        }
    }

    /// Blend a `patch × patch` pixel block (row-major, rgb-interleaved,
    /// e.g. a concept code) into the frame at (y0, x0).
    pub fn blend_block(&mut self, y0: usize, x0: usize, patch: usize, block: &[f32], alpha: f32) {
        assert_eq!(block.len(), patch * patch * 3);
        for dy in 0..patch {
            for dx in 0..patch {
                let b = (dy * patch + dx) * 3;
                self.blend_rgb(
                    y0 + dy,
                    x0 + dx,
                    [block[b], block[b + 1], block[b + 2]],
                    alpha,
                );
            }
        }
    }

    /// Clamp all values into [0, 1].
    pub fn clamp(&mut self) {
        for v in &mut self.data {
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// Mean per-pixel L2 distance to another frame (clustering metric).
    pub fn l2_distance(&self, other: &Frame) -> f32 {
        self.l2_distance_bounded(other, f32::INFINITY)
    }

    /// L2 distance with an early-exit bound: returns a value > `bound` as
    /// soon as the partial sum proves the final distance exceeds it.  The
    /// clustering inner loop only needs "is this within threshold / is it
    /// the running minimum", so most comparisons abort after a fraction
    /// of the pixels (§Perf: 2.9× on the clusterer hot path).
    pub fn l2_distance_bounded(&self, other: &Frame, bound: f32) -> f32 {
        assert_eq!(self.size, other.size);
        let n = self.data.len();
        let limit = if bound.is_finite() {
            bound * bound * n as f32
        } else {
            f32::INFINITY
        };
        let mut acc = 0.0f32;
        let mut i = 0;
        // check the abort condition once per 512-element block
        while i < n {
            let end = (i + 512).min(n);
            let (mut s0, mut s1) = (0.0f32, 0.0f32);
            let mut j = i;
            let end2 = end & !1;
            while j < end2 {
                let d0 = self.data[j] - other.data[j];
                let d1 = self.data[j + 1] - other.data[j + 1];
                s0 += d0 * d0;
                s1 += d1 * d1;
                j += 2;
            }
            if j < end {
                let d = self.data[j] - other.data[j];
                s0 += d * d;
            }
            acc += s0 + s1;
            if acc > limit {
                return (acc / n as f32).sqrt();
            }
            i = end;
        }
        (acc / n as f32).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filled_and_accessors() {
        let f = Frame::filled(8, [0.1, 0.2, 0.3]);
        assert_eq!(f.rgb(3, 4), (0.1, 0.2, 0.3));
        assert_eq!(f.data().len(), 8 * 8 * 3);
    }

    #[test]
    fn blend_block_plants_code() {
        let mut f = Frame::filled(16, [0.0, 0.0, 0.0]);
        let block = vec![1.0f32; 4 * 4 * 3];
        f.blend_block(0, 0, 4, &block, 0.8);
        assert_eq!(f.rgb(0, 0), (0.8, 0.8, 0.8));
        assert_eq!(f.rgb(3, 3), (0.8, 0.8, 0.8));
        assert_eq!(f.rgb(4, 4), (0.0, 0.0, 0.0));
    }

    #[test]
    fn l2_distance_properties() {
        let a = Frame::filled(8, [0.0; 3]);
        let b = Frame::filled(8, [1.0; 3]);
        assert_eq!(a.l2_distance(&a), 0.0);
        assert!((a.l2_distance(&b) - 1.0).abs() < 1e-6);
        assert_eq!(a.l2_distance(&b), b.l2_distance(&a));
    }

    #[test]
    fn clamp_bounds() {
        let mut f = Frame::from_data(2, vec![-1.0, 0.5, 2.0, 0.0, 1.0, 0.3, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        f.clamp();
        assert!(f.data().iter().all(|&x| (0.0..=1.0).contains(&x)));
    }
}
