//! Video substrate: frame representation, the procedural scene-scripted
//! stream generator (stands in for the paper's edge-camera footage), and
//! the VQA workload generator with planted ground truth.

pub mod frame;
pub mod synth;
pub mod workload;

pub use frame::Frame;
pub use synth::{SceneScript, SynthConfig, VideoSynth};
pub use workload::{DatasetPreset, Query, QueryType, WorkloadGen};
