//! Procedural scene-scripted video generator.
//!
//! Stands in for the paper's edge camera streams (Video-MME / EgoSchema
//! clips): a seeded *scene script* fixes scene boundaries, palettes,
//! textures, moving objects, and concept events; frames are rendered
//! deterministically from `(seed, frame_index)` so any frame can be
//! produced by random access without sequential state.
//!
//! Scene changes move the palette/texture abruptly (what Eq. 1 detects);
//! within a scene, slow drift plus a moving blob provide the intra-scene
//! variation that frame clustering groups; concept events plant the
//! concept pixel codes (shared with the Python model via
//! `artifacts/concept_codes.bin`) into the watermark patches that the
//! image tower reads out — giving the synthetic stream exactly the
//! properties the paper's pipeline exploits, with ground truth attached.

use crate::util::rng::Pcg64;
use crate::video::frame::Frame;

/// A concept visibility event inside a scene.
#[derive(Clone, Debug)]
pub struct ConceptEvent {
    pub concept: usize,
    /// global frame range [start, end)
    pub start: u64,
    pub end: u64,
    /// watermark slot: 0 = top-left patch, 1 = top-right patch
    pub slot: u8,
}

/// One scene of the script.
#[derive(Clone, Debug)]
pub struct SceneSpec {
    pub id: usize,
    pub start: u64,
    pub len: u64,
    pub base_rgb: [f32; 3],
    pub tex_freq: f32,
    pub tex_phase: f32,
    pub drift: [f32; 3],
    pub blob_rgb: [f32; 3],
    pub blob_radius: f32,
    pub blob_speed: f32,
    pub events: Vec<ConceptEvent>,
}

impl SceneSpec {
    pub fn end(&self) -> u64 {
        self.start + self.len
    }
}

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub frame_size: usize,
    pub fps: f64,
    pub duration_s: f64,
    /// scene duration range, seconds
    pub scene_len_s: (f64, f64),
    /// events per scene range (inclusive)
    pub events_per_scene: (usize, usize),
    /// fraction of the scene a concept event spans
    pub event_fraction: f64,
    /// per-pixel temporal noise amplitude
    pub noise: f32,
    /// watermark blend weight (code vs scene content)
    pub code_blend: f32,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            frame_size: 64,
            fps: 8.0,
            duration_s: 120.0,
            scene_len_s: (6.0, 18.0),
            events_per_scene: (0, 2),
            event_fraction: 0.5,
            noise: 0.015,
            code_blend: 0.8,
            seed: 1,
        }
    }
}

/// The full script: scenes + derived ground truth.
#[derive(Clone, Debug)]
pub struct SceneScript {
    pub scenes: Vec<SceneSpec>,
    pub total_frames: u64,
    pub fps: f64,
}

impl SceneScript {
    /// Generate a script from config; concepts are drawn from
    /// `[0, n_concepts)`.
    pub fn generate(cfg: &SynthConfig, n_concepts: usize) -> Self {
        let mut rng = Pcg64::new(cfg.seed, SCRIPT_STREAM);
        let total_frames = (cfg.duration_s * cfg.fps).round() as u64;
        let mut scenes = Vec::new();
        let mut start = 0u64;
        let mut id = 0usize;
        while start < total_frames {
            let len_s =
                cfg.scene_len_s.0 + rng.f64() * (cfg.scene_len_s.1 - cfg.scene_len_s.0);
            let len = ((len_s * cfg.fps).round() as u64)
                .max(2)
                .min(total_frames - start);
            let n_events =
                rng.range(cfg.events_per_scene.0, cfg.events_per_scene.1 + 1);
            let mut events = Vec::with_capacity(n_events);
            for slot in 0..n_events.min(2) {
                let concept = rng.below(n_concepts as u64) as usize;
                let span = ((len as f64 * cfg.event_fraction) as u64).max(1);
                let offset = if len > span { rng.below(len - span) } else { 0 };
                events.push(ConceptEvent {
                    concept,
                    start: start + offset,
                    end: start + offset + span,
                    slot: slot as u8,
                });
            }
            scenes.push(SceneSpec {
                id,
                start,
                len,
                base_rgb: [
                    0.15 + 0.7 * rng.f32(),
                    0.15 + 0.7 * rng.f32(),
                    0.15 + 0.7 * rng.f32(),
                ],
                tex_freq: 1.0 + 7.0 * rng.f32(),
                tex_phase: rng.f32() * std::f32::consts::TAU,
                drift: [
                    0.04 * (rng.f32() - 0.5),
                    0.04 * (rng.f32() - 0.5),
                    0.04 * (rng.f32() - 0.5),
                ],
                blob_rgb: [rng.f32(), rng.f32(), rng.f32()],
                blob_radius: 4.0 + 8.0 * rng.f32(),
                blob_speed: 0.3 + 1.2 * rng.f32(),
                events,
            });
            start += len;
            id += 1;
        }
        Self { scenes, total_frames, fps: cfg.fps }
    }

    /// Scene containing a frame (scenes tile the stream).
    pub fn scene_at(&self, frame: u64) -> &SceneSpec {
        let i = self
            .scenes
            .partition_point(|s| s.end() <= frame)
            .min(self.scenes.len() - 1);
        &self.scenes[i]
    }

    /// Ground-truth scene boundaries (first frame of each scene, except 0).
    pub fn boundaries(&self) -> Vec<u64> {
        self.scenes.iter().skip(1).map(|s| s.start).collect()
    }

    /// Concepts visible at a frame, with their slots.
    pub fn concepts_at(&self, frame: u64) -> Vec<(usize, u8)> {
        self.scene_at(frame)
            .events
            .iter()
            .filter(|e| frame >= e.start && frame < e.end)
            .map(|e| (e.concept, e.slot))
            .collect()
    }

    /// All visibility spans of a concept across the video.
    pub fn concept_spans(&self, concept: usize) -> Vec<(u64, u64)> {
        self.scenes
            .iter()
            .flat_map(|s| s.events.iter())
            .filter(|e| e.concept == concept)
            .map(|e| (e.start, e.end))
            .collect()
    }

    /// Concepts that appear anywhere, with span counts.
    pub fn concept_census(&self) -> Vec<(usize, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for s in &self.scenes {
            for e in &s.events {
                *counts.entry(e.concept).or_insert(0usize) += 1;
            }
        }
        counts.into_iter().collect()
    }
}

/// RNG stream id for script generation (distinct from render noise).
const SCRIPT_STREAM: u64 = 0x5ce7e;

/// Deterministic per-pixel hash noise in [-1, 1].
#[inline]
fn hash_noise(seed: u64, frame: u64, y: usize, x: usize) -> f32 {
    let mut h = seed
        ^ frame.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (y as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (x as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    ((h >> 40) as f32) * (2.0 / (1u64 << 24) as f32) - 1.0
}

/// Frame renderer: deterministic random access over the script.
pub struct VideoSynth {
    cfg: SynthConfig,
    script: SceneScript,
    /// concept pixel codes from artifacts (`[n_concepts][patch·patch·3]`)
    codes: Vec<Vec<f32>>,
    patch: usize,
}

impl VideoSynth {
    pub fn new(cfg: SynthConfig, codes: Vec<Vec<f32>>, patch: usize) -> Self {
        let n_concepts = codes.len();
        let script = SceneScript::generate(&cfg, n_concepts);
        Self { cfg, script, codes, patch }
    }

    /// Construct with a pre-built script (for tests / curated workloads).
    pub fn with_script(
        cfg: SynthConfig,
        script: SceneScript,
        codes: Vec<Vec<f32>>,
        patch: usize,
    ) -> Self {
        Self { cfg, script, codes, patch }
    }

    pub fn script(&self) -> &SceneScript {
        &self.script
    }

    pub fn config(&self) -> &SynthConfig {
        &self.cfg
    }

    pub fn total_frames(&self) -> u64 {
        self.script.total_frames
    }

    /// The concept code book this stream plants (shared with the MEM).
    pub fn codes(&self) -> &[Vec<f32>] {
        &self.codes
    }

    /// Watermark patch side length.
    pub fn patch(&self) -> usize {
        self.patch
    }

    /// Render frame `idx`.
    pub fn frame(&self, idx: u64) -> Frame {
        let size = self.cfg.frame_size;
        let scene = self.script.scene_at(idx);
        let t = (idx - scene.start) as f32;

        let mut f = Frame::new(size);
        let inv = 1.0 / (size - 1) as f32;
        // slow within-scene drift
        let drift = [
            scene.drift[0] * t * 0.1,
            scene.drift[1] * t * 0.1,
            scene.drift[2] * t * 0.1,
        ];
        for y in 0..size {
            let fy = y as f32 * inv;
            for x in 0..size {
                let fx = x as f32 * inv;
                // palette gradient + sinusoidal texture
                let tex = 0.12
                    * (scene.tex_freq * (fx + 0.6 * fy) * std::f32::consts::TAU
                        + scene.tex_phase)
                        .sin();
                let n = self.cfg.noise * hash_noise(self.cfg.seed, idx, y, x);
                let rgb = [
                    scene.base_rgb[0] + 0.25 * fx + tex + drift[0] + n,
                    scene.base_rgb[1] + 0.25 * fy + tex + drift[1] + n,
                    scene.base_rgb[2] - 0.15 * fx + tex + drift[2] + n,
                ];
                f.set_rgb(y, x, rgb);
            }
        }

        // moving blob (intra-scene variation for clustering)
        let cx = (size as f32 * 0.5)
            + (size as f32 * 0.3) * (scene.blob_speed * t * 0.05).sin();
        let cy = (size as f32 * 0.5)
            + (size as f32 * 0.3) * (scene.blob_speed * t * 0.05 + 1.3).cos();
        let r2 = scene.blob_radius * scene.blob_radius;
        let lo_y = ((cy - scene.blob_radius).floor().max(0.0)) as usize;
        let hi_y = ((cy + scene.blob_radius).ceil().min(size as f32 - 1.0)) as usize;
        let lo_x = ((cx - scene.blob_radius).floor().max(0.0)) as usize;
        let hi_x = ((cx + scene.blob_radius).ceil().min(size as f32 - 1.0)) as usize;
        for y in lo_y..=hi_y {
            for x in lo_x..=hi_x {
                let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                if d2 < r2 {
                    f.blend_rgb(y, x, scene.blob_rgb, 0.85);
                }
            }
        }

        // concept events: a visible activity overlay (events are visible
        // actions — this is what scene-change detection and clustering key
        // on) plus the watermark block (the semantic signal the MEM reads
        // out through the shared code book)
        for (concept, slot) in self.script.concepts_at(idx) {
            // activity blob: concept-dependent color/position
            let code = &self.codes[concept];
            let acx = (size as f32) * (0.25 + 0.5 * code[0]);
            let acy = (size as f32) * (0.35 + 0.4 * code[1]);
            let argb = [code[2], code[3], code[4]];
            let ar = 7.0f32;
            let lo_y = ((acy - ar).floor().max(0.0)) as usize;
            let hi_y = ((acy + ar).ceil().min(size as f32 - 1.0)) as usize;
            let lo_x = ((acx - ar).floor().max(0.0)) as usize;
            let hi_x = ((acx + ar).ceil().min(size as f32 - 1.0)) as usize;
            for y in lo_y..=hi_y {
                for x in lo_x..=hi_x {
                    let d2 = (y as f32 - acy).powi(2) + (x as f32 - acx).powi(2);
                    if d2 < ar * ar {
                        f.blend_rgb(y, x, argb, 0.9);
                    }
                }
            }
            // watermark block in the slot's corner patch
            let x0 = if slot == 0 { 0 } else { size - self.patch };
            f.blend_block(0, x0, self.patch, code, self.cfg.code_blend);
        }

        f.clamp();
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(n: usize, patch: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(7);
        (0..n)
            .map(|_| (0..patch * patch * 3).map(|_| rng.f32()).collect())
            .collect()
    }

    fn synth() -> VideoSynth {
        VideoSynth::new(SynthConfig::default(), codes(8, 8), 8)
    }

    #[test]
    fn script_tiles_stream() {
        let s = synth();
        let script = s.script();
        assert_eq!(script.scenes[0].start, 0);
        for w in script.scenes.windows(2) {
            assert_eq!(w[0].end(), w[1].start);
        }
        assert_eq!(script.scenes.last().unwrap().end(), script.total_frames);
    }

    #[test]
    fn deterministic_rendering() {
        let a = synth().frame(123);
        let b = synth().frame(123);
        assert_eq!(a, b);
    }

    #[test]
    fn scene_change_is_bigger_than_within_scene_change() {
        let s = synth();
        let script = s.script();
        let b = script.scenes[1].start;
        let across = s.frame(b - 1).l2_distance(&s.frame(b));
        let within = s.frame(b).l2_distance(&s.frame(b + 1));
        assert!(
            across > 2.0 * within,
            "across {across} vs within {within}"
        );
    }

    #[test]
    fn concepts_visible_during_event_only() {
        let s = synth();
        let script = s.script();
        let ev = script
            .scenes
            .iter()
            .flat_map(|sc| sc.events.iter())
            .next()
            .expect("some event");
        assert!(script
            .concepts_at(ev.start)
            .iter()
            .any(|&(c, _)| c == ev.concept));
        if ev.end < script.total_frames {
            let sc = script.scene_at(ev.start);
            if ev.end < sc.end() {
                assert!(!script
                    .concepts_at(ev.end)
                    .iter()
                    .any(|&(c, slot)| c == ev.concept && slot == ev.slot));
            }
        }
    }

    #[test]
    fn watermark_pixels_reflect_code() {
        let s = synth();
        let script = s.script();
        let ev = script
            .scenes
            .iter()
            .flat_map(|sc| sc.events.iter())
            .find(|e| e.slot == 0)
            .expect("slot-0 event");
        let f = s.frame(ev.start);
        // top-left pixel should be ~0.8·code + 0.2·scene
        let code = &s.codes[ev.concept];
        let (r, _, _) = f.rgb(0, 0);
        // blended value lies within 0.2 of the code value (scene term bounded)
        assert!((r - code[0]).abs() < 0.25, "r {r} vs code {}", code[0]);
    }

    #[test]
    fn concept_spans_cover_events() {
        let s = synth();
        let script = s.script();
        for (c, n) in script.concept_census() {
            assert_eq!(script.concept_spans(c).len(), n);
        }
    }

    #[test]
    fn frames_in_unit_range() {
        let s = synth();
        for idx in [0, 7, 100] {
            assert!(s
                .frame(idx)
                .data()
                .iter()
                .all(|&x| (0.0..=1.0).contains(&x)));
        }
    }
}
