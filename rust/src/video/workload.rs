//! VQA workload generator: natural-language queries over a scene script,
//! with planted ground truth (evidence spans + correct option).
//!
//! Substitutes for Video-MME / EgoSchema (unavailable here): each query
//! targets one or more *concepts* that the script plants into the video;
//! the evidence spans are exactly the frames where the queried concept is
//! visible.  Two query types mirror Fig. 9:
//!   - `Localized`: one narrow span (e.g. "did the person take the pill") —
//!     a few frames suffice;
//!   - `Dispersed`: a concept with several spans across scenes, or a
//!     multi-concept comparison — broad coverage is required.

use crate::util::rng::Pcg64;
use crate::video::synth::SceneScript;

/// Query evidence geometry (Fig. 9's two distribution shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryType {
    Localized,
    Dispersed,
}

/// A multiple-choice VQA query with ground truth attached.
#[derive(Clone, Debug)]
pub struct Query {
    pub id: usize,
    pub text: String,
    /// queried concept ids (1 for localized, ≥1 for dispersed)
    pub concepts: Vec<usize>,
    /// ground-truth evidence frame spans [start, end)
    pub evidence: Vec<(u64, u64)>,
    pub qtype: QueryType,
    /// number of answer options (4 = Video-MME-like, 5 = EgoSchema-like)
    pub n_options: usize,
    /// concepts behind the distractor options (for the answer model)
    pub distractor_concepts: Vec<usize>,
}

impl Query {
    /// Total evidence frames.
    pub fn evidence_frames(&self) -> u64 {
        self.evidence.iter().map(|(s, e)| e - s).sum()
    }

    /// Does `frame` fall inside any evidence span?
    pub fn covers(&self, frame: u64) -> bool {
        self.evidence.iter().any(|&(s, e)| frame >= s && frame < e)
    }
}

/// Dataset presets mirroring the paper's benchmarks (durations, option
/// counts, query mix).  Communication/VLM cost models consume the
/// *realistic* duration; the pixel stream itself is 64×64 synthetic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetPreset {
    VideoMmeShort,
    VideoMmeMedium,
    VideoMmeLong,
    EgoSchema,
}

impl DatasetPreset {
    pub fn name(&self) -> &'static str {
        match self {
            Self::VideoMmeShort => "videomme-short",
            Self::VideoMmeMedium => "videomme-medium",
            Self::VideoMmeLong => "videomme-long",
            Self::EgoSchema => "egoschema",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "videomme-short" => Some(Self::VideoMmeShort),
            "videomme-medium" => Some(Self::VideoMmeMedium),
            "videomme-long" => Some(Self::VideoMmeLong),
            "egoschema" => Some(Self::EgoSchema),
            _ => None,
        }
    }

    /// Clip duration in seconds (midpoint of the benchmark's range).
    pub fn duration_s(&self) -> f64 {
        match self {
            Self::VideoMmeShort => 90.0,     // ≤ 2 min
            Self::VideoMmeMedium => 540.0,   // 4–15 min
            Self::VideoMmeLong => 2700.0,    // 30–60 min
            Self::EgoSchema => 180.0,        // 3 min egocentric clips
        }
    }

    pub fn n_options(&self) -> usize {
        match self {
            Self::EgoSchema => 5,
            _ => 4,
        }
    }

    /// Scene-length range: egocentric video cuts faster.
    pub fn scene_len_s(&self) -> (f64, f64) {
        match self {
            Self::EgoSchema => (3.0, 10.0),
            _ => (6.0, 20.0),
        }
    }

    /// Fraction of dispersed queries in the mix.
    pub fn dispersed_fraction(&self) -> f64 {
        match self {
            Self::EgoSchema => 0.6, // long-horizon egocentric reasoning
            Self::VideoMmeLong => 0.5,
            Self::VideoMmeMedium => 0.4,
            Self::VideoMmeShort => 0.3,
        }
    }

    pub fn all() -> [DatasetPreset; 4] {
        [
            Self::VideoMmeShort,
            Self::VideoMmeMedium,
            Self::VideoMmeLong,
            Self::EgoSchema,
        ]
    }
}

const FILLERS: &[&str] = &[
    "what happened with",
    "when did the person use",
    "show me the moment involving",
    "was there any activity with",
    "which option describes",
    "how many times did we see",
];

/// Generate a query set over a script.
pub struct WorkloadGen {
    rng: Pcg64,
    n_options: usize,
    dispersed_fraction: f64,
}

impl WorkloadGen {
    pub fn new(seed: u64, preset: DatasetPreset) -> Self {
        Self {
            rng: Pcg64::new(seed, 0x9e7),
            n_options: preset.n_options(),
            dispersed_fraction: preset.dispersed_fraction(),
        }
    }

    /// Generate `n` queries with ground truth from the script.  Concepts
    /// that never appear are used as distractors.
    pub fn generate(&mut self, script: &SceneScript, n: usize) -> Vec<Query> {
        let census = script.concept_census();
        if census.is_empty() {
            return Vec::new();
        }
        let multi: Vec<usize> = census
            .iter()
            .filter(|&&(_, cnt)| cnt >= 2)
            .map(|&(c, _)| c)
            .collect();
        let single: Vec<usize> = census
            .iter()
            .filter(|&&(_, cnt)| cnt == 1)
            .map(|&(c, _)| c)
            .collect();
        let present: Vec<usize> = census.iter().map(|&(c, _)| c).collect();

        let mut out = Vec::with_capacity(n);
        for id in 0..n {
            let want_dispersed = self.rng.chance(self.dispersed_fraction);
            let (qtype, concepts) = if want_dispersed && !multi.is_empty() {
                let c = multi[self.rng.range(0, multi.len())];
                (QueryType::Dispersed, vec![c])
            } else if !single.is_empty() {
                let c = single[self.rng.range(0, single.len())];
                (QueryType::Localized, vec![c])
            } else {
                let c = present[self.rng.range(0, present.len())];
                let qt = if script.concept_spans(c).len() >= 2 {
                    QueryType::Dispersed
                } else {
                    QueryType::Localized
                };
                (qt, vec![c])
            };

            let mut evidence: Vec<(u64, u64)> = concepts
                .iter()
                .flat_map(|&c| script.concept_spans(c))
                .collect();
            evidence.sort_unstable();

            // distractor options reference other concepts
            let mut distractors = Vec::new();
            let mut guard = 0;
            while distractors.len() < self.n_options - 1 && guard < 100 {
                let c = present[self.rng.range(0, present.len())];
                if !concepts.contains(&c) && !distractors.contains(&c) {
                    distractors.push(c);
                }
                guard += 1;
            }

            let filler = FILLERS[self.rng.range(0, FILLERS.len())];
            let names: Vec<String> = concepts
                .iter()
                .map(|c| format!("concept{c:02}"))
                .collect();
            out.push(Query {
                id,
                text: format!("{filler} {} in the video", names.join(" and ")),
                concepts,
                evidence,
                qtype,
                n_options: self.n_options,
                distractor_concepts: distractors,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::synth::{SceneScript, SynthConfig};

    fn script() -> SceneScript {
        let cfg = SynthConfig { duration_s: 240.0, seed: 3, ..Default::default() };
        SceneScript::generate(&cfg, 16)
    }

    #[test]
    fn queries_have_evidence() {
        let s = script();
        let qs = WorkloadGen::new(1, DatasetPreset::VideoMmeShort).generate(&s, 50);
        assert_eq!(qs.len(), 50);
        for q in &qs {
            assert!(!q.evidence.is_empty(), "query {} has no evidence", q.id);
            assert!(q.evidence_frames() > 0);
            assert_eq!(q.n_options, 4);
        }
    }

    #[test]
    fn dispersed_queries_have_multiple_spans() {
        let s = script();
        let qs = WorkloadGen::new(2, DatasetPreset::EgoSchema).generate(&s, 80);
        let dispersed: Vec<_> =
            qs.iter().filter(|q| q.qtype == QueryType::Dispersed).collect();
        assert!(!dispersed.is_empty());
        for q in dispersed {
            assert!(q.evidence.len() >= 2, "dispersed with {} spans", q.evidence.len());
        }
    }

    #[test]
    fn covers_is_consistent_with_spans() {
        let s = script();
        let qs = WorkloadGen::new(3, DatasetPreset::VideoMmeShort).generate(&s, 10);
        for q in &qs {
            let (start, end) = q.evidence[0];
            assert!(q.covers(start));
            assert!(q.covers(end - 1));
            assert!(!q.covers(end) || q.evidence.iter().any(|&(s2, e2)| end >= s2 && end < e2));
        }
    }

    #[test]
    fn distractors_disjoint_from_answer() {
        let s = script();
        let qs = WorkloadGen::new(4, DatasetPreset::EgoSchema).generate(&s, 30);
        for q in &qs {
            for d in &q.distractor_concepts {
                assert!(!q.concepts.contains(d));
            }
            assert_eq!(q.n_options, 5);
        }
    }

    #[test]
    fn deterministic_generation() {
        let s = script();
        let a = WorkloadGen::new(9, DatasetPreset::VideoMmeShort).generate(&s, 20);
        let b = WorkloadGen::new(9, DatasetPreset::VideoMmeShort).generate(&s, 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.evidence, y.evidence);
        }
    }

    #[test]
    fn presets_roundtrip_names() {
        for p in DatasetPreset::all() {
            assert_eq!(DatasetPreset::parse(p.name()), Some(p));
        }
    }
}
