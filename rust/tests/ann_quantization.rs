//! Recall gate + format-compat suite for the quantized/coarse cold tier
//! (tier-1: `cargo test` runs this; DESIGN.md §Quantization-and-ANN).
//!
//! The exactness contract has two halves:
//!  * exact mode (`quantization = "none"`, `coarse_nprobe = 0`) stays
//!    selection-bit-identical — covered here by the v1-compat test and
//!    by the restart-equivalence suite in `memory_recovery.rs`;
//!  * quantized+coarse mode is an opt-in approximation gated on
//!    recall@k ≥ 0.95 against exact-mode selection (k = the retrieval
//!    sampling budget) — covered by `recall_gate_holds` below.

use std::path::PathBuf;

use venus::config::{MemoryConfig, RetrievalConfig};
use venus::memory::{ClusterRecord, Hierarchy, StreamId};
use venus::util::rng::Pcg64;

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "venus-annq-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

const D: usize = 32;
const CLUSTERS: usize = 8;

/// Unit-norm cluster centers, deterministic.
fn centers(rng: &mut Pcg64) -> Vec<Vec<f32>> {
    (0..CLUSTERS)
        .map(|_| {
            let mut c: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut c);
            c
        })
        .collect()
}

/// Fill a shard with `n` records in cluster-coherent *runs* (the stream
/// dwells on one scene before moving on — what temporal locality gives a
/// real camera), so sealed segments are cluster-coherent and the coarse
/// index has structure to route on.
fn fill(h: &mut Hierarchy, n: usize, run: usize, seed: u64) {
    let mut rng = Pcg64::seeded(seed);
    let cs = centers(&mut rng);
    for i in 0..n {
        let c = &cs[(i / run) % CLUSTERS];
        let mut v: Vec<f32> = c
            .iter()
            .map(|x| x + 0.15 * rng.normal())
            .collect();
        venus::util::l2_normalize(&mut v);
        h.archive_frame(i as u64, &venus::video::frame::Frame::filled(8, [0.5; 3]))
            .unwrap();
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: i,
                centroid_frame: i as u64,
                members: vec![i as u64],
            },
        )
        .unwrap();
    }
}

/// Top-k ids by score, deterministic tie-break on id.
fn topk(scores: &[f32], k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order.truncate(k);
    order
}

/// Cold-heavy config: segments of 256 records, hot budget ≈ 2 segments.
fn cold_heavy(quantized: bool, nprobe: usize, centroids: usize) -> MemoryConfig {
    let rec_bytes = D * 4 + std::mem::size_of::<ClusterRecord>() + 8;
    MemoryConfig {
        segment_records: 256,
        hot_budget_bytes: 2 * 256 * rec_bytes,
        cold_cache_segments: 64,
        quantization: if quantized { "sq8".into() } else { "none".into() },
        coarse_nprobe: nprobe,
        coarse_centroids_per_segment: centroids,
        ..Default::default()
    }
}

/// The gate the ISSUE's acceptance criterion names: quantized+coarse
/// selection keeps recall@k ≥ 0.95 against exact-mode selection, k =
/// the retrieval sampling budget.
#[test]
fn recall_gate_holds() {
    let tmp = TempDir::new("recall");
    let n = 4096;
    let run = 256; // one segment per cluster dwell
    let k = RetrievalConfig::default().budget; // the sampling budget

    let mut exact =
        Hierarchy::durable(&cold_heavy(false, 0, 0), D, StreamId(0), &tmp.0.join("exact"), 8)
            .unwrap();
    fill(&mut exact, n, run, 42);
    let mut approx =
        Hierarchy::durable(&cold_heavy(true, 4, 8), D, StreamId(0), &tmp.0.join("approx"), 8)
            .unwrap();
    fill(&mut approx, n, run, 42);

    let ts = approx.tier_stats();
    assert!(
        ts.cold_records > 3 * n / 4,
        "tier split is not cold-heavy: {ts:?}"
    );
    assert!(ts.cold_quantized, "approx shard must report quantized scans");

    let mut rng = Pcg64::seeded(7);
    let cs = centers(&mut Pcg64::seeded(42)); // same centers fill() used
    let mut total_overlap = 0usize;
    let queries = 32;
    let (mut se, mut sa) = (Vec::new(), Vec::new());
    for qi in 0..queries {
        let c = &cs[qi % CLUSTERS];
        let mut q: Vec<f32> = c.iter().map(|x| x + 0.1 * rng.normal()).collect();
        venus::util::l2_normalize(&mut q);
        exact.score_all(&q, &mut se).unwrap();
        approx.score_all(&q, &mut sa).unwrap();
        assert_eq!(se.len(), sa.len());
        let want = topk(&se, k);
        let got = topk(&sa, k);
        total_overlap += want.iter().filter(|id| got.contains(id)).count();
    }
    let recall = total_overlap as f64 / (queries * k) as f64;
    assert!(
        recall >= 0.95,
        "recall@{k} = {recall:.3} under sq8 + coarse_nprobe=4 (need >= 0.95)"
    );

    // the observability gauges saw the pruning: far fewer segments
    // scanned than considered
    let ts = approx.tier_stats();
    assert!(
        ts.cold_probe_segments < ts.cold_probe_candidates / 2,
        "coarse probing never pruned: {ts:?}"
    );
}

/// Segments sealed by the v1 (plain f32) code path — i.e. with default
/// options — must open and score **bit-identically** when the shard is
/// reopened with quantization and coarse probing configured: new
/// options only shape *future* seals, and v1 segments have no SQ8
/// region to scan and no centroids to prune on.
#[test]
fn v1_segments_score_identically_under_quantized_config() {
    let tmp = TempDir::new("v1compat");
    let n = 1024;
    let run = 256;

    // seal everything with the v1 layout
    {
        let mut h =
            Hierarchy::durable(&cold_heavy(false, 0, 0), D, StreamId(0), &tmp.0, 8).unwrap();
        fill(&mut h, n, run, 9);
        h.flush().unwrap();
    }
    let queries: Vec<Vec<f32>> = {
        let mut rng = Pcg64::seeded(11);
        (0..8)
            .map(|_| {
                let mut q: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
                venus::util::l2_normalize(&mut q);
                q
            })
            .collect()
    };
    // ground truth: reopen in exact mode
    let mut ground = Vec::new();
    {
        let exact =
            Hierarchy::durable(&cold_heavy(false, 0, 0), D, StreamId(0), &tmp.0, 8).unwrap();
        assert_eq!(exact.len(), n);
        for q in &queries {
            let mut s = Vec::new();
            exact.score_all(q, &mut s).unwrap();
            ground.push(s);
        }
    }
    // reopen the SAME directory in quantized+coarse mode
    let approx = Hierarchy::durable(&cold_heavy(true, 2, 8), D, StreamId(0), &tmp.0, 8).unwrap();
    assert_eq!(approx.len(), n);
    let mut sa = Vec::new();
    for (q, se) in queries.iter().zip(&ground) {
        approx.score_all(q, &mut sa).unwrap();
        assert_eq!(se.len(), sa.len());
        for (i, (x, y)) in se.iter().zip(&sa).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "score {i} drifts on a v1 segment under quantized config"
            );
        }
    }
}

/// Mixed-format stream: v1 segments sealed by the old path stay exact
/// while *new* seals pick up SQ8 + centroids — and the shard keeps
/// recovering across restarts with the mixed manifest.
#[test]
fn mixed_v1_v2_stream_recovers_and_scores() {
    let tmp = TempDir::new("mixed");
    let run = 256;
    {
        let mut h =
            Hierarchy::durable(&cold_heavy(false, 0, 0), D, StreamId(0), &tmp.0, 8).unwrap();
        fill(&mut h, 1024, run, 5); // 4 v1 segments (some demoted)
        h.flush().unwrap();
    }
    {
        // reopen quantized: extend the stream with v2 seals
        let mut h =
            Hierarchy::durable(&cold_heavy(true, 0, 8), D, StreamId(0), &tmp.0, 8).unwrap();
        let mut rng = Pcg64::seeded(6);
        let cs = centers(&mut Pcg64::seeded(5));
        for i in 1024..2048usize {
            let c = &cs[(i / run) % CLUSTERS];
            let mut v: Vec<f32> = c.iter().map(|x| x + 0.15 * rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            h.archive_frame(i as u64, &venus::video::frame::Frame::filled(8, [0.5; 3]))
                .unwrap();
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: i,
                    centroid_frame: i as u64,
                    members: vec![i as u64],
                },
            )
            .unwrap();
        }
        h.flush().unwrap();
        h.check_invariants().unwrap();
    }
    // restart once more: the mixed manifest (3-field v1 lines + 4-field
    // v2 lines) recovers, and queries span both formats
    let h = Hierarchy::durable(&cold_heavy(true, 0, 8), D, StreamId(0), &tmp.0, 8).unwrap();
    assert_eq!(h.len(), 2048);
    h.check_invariants().unwrap();
    let mut rng = Pcg64::seeded(12);
    let mut q: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
    venus::util::l2_normalize(&mut q);
    let mut scores = Vec::new();
    h.score_all(&q, &mut scores).unwrap();
    assert_eq!(scores.len(), 2048);
    assert!(scores.iter().all(|s| s.is_finite()), "nprobe=0 must scan everything");
}
