//! Serving API v1 integration properties: semantic query-cache
//! correctness against a real engine (hit == cold selection, staleness
//! invalidation, scope isolation), deadline shedding, and priority-lane
//! accounting — all over the real native embed backend.

use std::sync::Arc;

use venus::util::sync::OrderedRwLock;
use std::time::Duration;

use venus::api::{ApiError, CacheStatus, Client, Priority, QueryCache, QueryRequest};
use venus::config::{MemoryConfig, RetrievalConfig, VenusConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::memory::{
    ClusterRecord, Hierarchy, InMemoryRaw, MemoryFabric, RawStore, StreamId, StreamScope,
};
use venus::server::Service;
use venus::util::rng::Pcg64;
use venus::video::frame::Frame;

/// A deterministic fabric: `streams` shards, each with `clusters`
/// random-unit-vector records over 4-frame clusters.
fn seeded_fabric(d: usize, streams: usize, clusters: u64, seed: u64) -> Arc<MemoryFabric> {
    let raws: Vec<Box<dyn RawStore>> =
        (0..streams).map(|_| Box::new(InMemoryRaw::new(8)) as Box<dyn RawStore>).collect();
    let fabric = Arc::new(MemoryFabric::new(&MemoryConfig::default(), d, raws).unwrap());
    let mut rng = Pcg64::seeded(seed);
    for sid in 0..streams as u16 {
        let shard = fabric.shard(StreamId(sid)).unwrap();
        let mut g = shard.write();
        for c in 0..clusters {
            for f in c * 4..(c + 1) * 4 {
                g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
            }
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            g.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(sid),
                    scene_id: c as usize,
                    centroid_frame: c * 4,
                    members: (c * 4..(c + 1) * 4).collect(),
                },
            )
            .unwrap();
        }
    }
    fabric
}

/// Append one extra cluster to a shard (advances its ingest watermark).
fn grow_shard(memory: &Arc<OrderedRwLock<Hierarchy>>, d: usize, rng: &mut Pcg64) {
    let mut g = memory.write();
    let start = g.frames_ingested();
    for f in start..start + 4 {
        g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
    }
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    venus::util::l2_normalize(&mut v);
    let stream = g.stream();
    g.insert(
        &v,
        ClusterRecord {
            stream,
            scene_id: (start / 4) as usize,
            centroid_frame: start,
            members: (start..start + 4).collect(),
        },
    )
    .unwrap();
}

fn engine_over(fabric: &Arc<MemoryFabric>, seed: u64) -> QueryEngine {
    QueryEngine::new(
        EmbedEngine::default_backend(false).unwrap(),
        Arc::clone(fabric),
        RetrievalConfig::default(),
        seed,
    )
}

/// Property: with no ingest in between, a cache hit returns exactly the
/// selection the cold query produced — same frames, same scores, same
/// draw count — for every retrieval mode and scope.
#[test]
fn cache_hit_replays_the_cold_selection_when_no_ingest() {
    let d = EmbedEngine::default_backend(false).unwrap().d_embed();
    let fabric = seeded_fabric(d, 2, 10, 0xa11);
    let mut qe = engine_over(&fabric, 5);
    let cache = QueryCache::new(64, 0.99, 1_000);

    let cases = [
        (StreamScope::All, RetrievalMode::Akr),
        (StreamScope::All, RetrievalMode::FixedSampling(8)),
        (StreamScope::One(StreamId(1)), RetrievalMode::FixedSampling(8)),
        (StreamScope::All, RetrievalMode::TopK(4)),
    ];
    for (scope, mode) in cases {
        let text = format!("what happened with concept01 under {scope:?} {mode:?}");
        let (cold, status) = qe
            .retrieve_request(&text, scope, Some(mode), None, Some(&cache))
            .unwrap();
        assert_eq!(status, CacheStatus::Miss, "{scope:?} {mode:?}");
        let (warm, status) = qe
            .retrieve_request(&text, scope, Some(mode), None, Some(&cache))
            .unwrap();
        assert_eq!(status, CacheStatus::HitExact, "{scope:?} {mode:?}");
        assert_eq!(warm.selection.frames, cold.selection.frames, "{scope:?} {mode:?}");
        assert_eq!(warm.frame_scores, cold.frame_scores, "{scope:?} {mode:?}");
        assert_eq!(warm.draws, cold.draws, "{scope:?} {mode:?}");
        assert_eq!(
            warm.timings.total_s(),
            0.0,
            "{scope:?} {mode:?}: exact hit skips the whole edge path"
        );
    }
    assert_eq!(cache.stats().hits_exact, cases.len() as u64);
}

/// Property: advancing a *touched* shard past the staleness bound
/// invalidates the entry (the repeat re-runs cold); advancing an
/// *untouched* shard leaves a scoped entry valid.
#[test]
fn ingest_watermarks_bound_cache_reuse() {
    let d = EmbedEngine::default_backend(false).unwrap().d_embed();
    let fabric = seeded_fabric(d, 2, 8, 0xbee);
    let mut qe = engine_over(&fabric, 7);
    let max_stale = 2u64;
    let cache = QueryCache::new(64, 0.99, max_stale);
    let mut rng = Pcg64::seeded(99);
    let mode = Some(RetrievalMode::FixedSampling(8));

    // an All-scope entry touches both shards
    let text = "what happened with concept01";
    let (_, status) = qe
        .retrieve_request(text, StreamScope::All, mode, None, Some(&cache))
        .unwrap();
    assert_eq!(status, CacheStatus::Miss);

    // within the bound: still a hit
    grow_shard(fabric.shard(StreamId(0)).unwrap(), d, &mut rng);
    let (_, status) = qe
        .retrieve_request(text, StreamScope::All, mode, None, Some(&cache))
        .unwrap();
    assert_eq!(status, CacheStatus::HitExact, "within the staleness bound");

    // past the bound on shard 0: the All-scope entry is invalidated
    for _ in 0..max_stale {
        grow_shard(fabric.shard(StreamId(0)).unwrap(), d, &mut rng);
    }
    let (_, status) = qe
        .retrieve_request(text, StreamScope::All, mode, None, Some(&cache))
        .unwrap();
    assert_eq!(status, CacheStatus::Miss, "touched shard advanced past the bound");
    assert_eq!(cache.stats().invalidated, 1);

    // a One(1)-scoped entry does not care how much shard 0 ingests
    let scoped = "what is on camera one";
    let one = StreamScope::One(StreamId(1));
    let (_, status) = qe.retrieve_request(scoped, one, mode, None, Some(&cache)).unwrap();
    assert_eq!(status, CacheStatus::Miss);
    for _ in 0..10 {
        grow_shard(fabric.shard(StreamId(0)).unwrap(), d, &mut rng);
    }
    let (_, status) = qe.retrieve_request(scoped, one, mode, None, Some(&cache)).unwrap();
    assert_eq!(status, CacheStatus::HitExact, "untouched shards don't invalidate");
    // ...but its own shard does
    for _ in 0..max_stale + 1 {
        grow_shard(fabric.shard(StreamId(1)).unwrap(), d, &mut rng);
    }
    let (_, status) = qe.retrieve_request(scoped, one, mode, None, Some(&cache)).unwrap();
    assert_eq!(status, CacheStatus::Miss);
    assert_eq!(cache.stats().invalidated, 2);
}

/// Deadline shedding: queries whose deadline passed while queued are
/// answered with the typed error, never executed, and participate in
/// conservation via the `deadline_shed` counters.
#[test]
fn expired_deadlines_shed_at_dequeue() {
    let d = EmbedEngine::default_backend(false).unwrap().d_embed();
    let fabric = seeded_fabric(d, 1, 4, 0xdead);
    let mut cfg = VenusConfig::default();
    cfg.server.workers = 1;
    let service = Service::start(&cfg, fabric, 31).unwrap();

    let mut receivers = Vec::new();
    for i in 0..6 {
        let request = QueryRequest::new(format!("doomed question {i}"))
            .priority(Priority::Batch)
            .deadline(Duration::ZERO);
        receivers.push(service.submit_request(request).expect("lane accepts"));
    }
    let mut shed = 0u64;
    for rx in receivers {
        match rx.recv().unwrap() {
            Err(ApiError::DeadlineExceeded) => shed += 1,
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    assert_eq!(shed, 6);
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.deadline_shed(), 6);
    assert_eq!(snap.batch.deadline_shed, 6);
    assert_eq!(snap.completed(), 0);
    assert_eq!(snap.total_p50_s, None, "nothing completed: percentiles are None");
    assert_eq!(snap.rejected(), 0, "shedding never pollutes rejection stats");
}

/// Sessions record their turns; mixed-priority traffic lands in the
/// right lane counters; generous deadlines never shed.
#[test]
fn sessions_record_history_and_lanes_account_traffic() {
    let d = EmbedEngine::default_backend(false).unwrap().d_embed();
    let fabric = seeded_fabric(d, 1, 6, 0x5e55);
    let cfg = VenusConfig::default();
    let service = Service::start(&cfg, fabric, 17).unwrap();
    let client = Client::new(&service);
    let mut session = client.session();

    let first = session
        .ask(
            QueryRequest::new("what happened with concept01")
                .priority(Priority::Interactive)
                .deadline(Duration::from_secs(60)),
        )
        .unwrap();
    assert_eq!(first.cache, CacheStatus::Miss);
    assert!(!first.evidence.is_empty());
    // evidence is structured: stream-tagged, timestamped, scored
    for e in &first.evidence {
        assert_eq!(e.stream(), StreamId(0));
        assert!((e.time_s - e.frame.idx as f64 / cfg.api.fps).abs() < 1e-12);
        assert!(e.score > 0.0);
    }

    let warm = session
        .ask(QueryRequest::new("what happened with concept01").priority(Priority::Batch))
        .unwrap();
    assert!(warm.cache.is_hit());
    assert_eq!(warm.frame_indices(), first.frame_indices());

    assert_eq!(session.history().len(), 2);
    assert_eq!(session.cache_hits(), 1);
    assert_eq!(session.errors(), 0);
    assert_eq!(session.id(), 0);
    assert_eq!(client.session().id(), 1, "session ids are per-client unique");
    assert!(client.cache_stats().hits() >= 1);

    let snap = service.shutdown();
    assert_eq!(snap.interactive.completed, 1);
    assert_eq!(snap.batch.completed, 1);
    assert_eq!(snap.deadline_shed(), 0);
}

/// The typed request survives the JSON wire format end-to-end: parse a
/// request off the wire, serve it, and re-encode the response.
#[test]
fn wire_round_trip_serves_a_parsed_request() {
    let d = EmbedEngine::default_backend(false).unwrap().d_embed();
    let fabric = seeded_fabric(d, 2, 6, 0x31e);
    let cfg = VenusConfig::default();
    let service = Service::start(&cfg, fabric, 13).unwrap();

    let wire = r#"{
        "text": "what happened with concept01",
        "scope": {"one": 1},
        "mode": {"fixed_sampling": 6},
        "budget": 4,
        "priority": "interactive",
        "deadline_ms": 60000
    }"#;
    let request = QueryRequest::from_json_str(wire).unwrap();
    assert_eq!(request.scope, StreamScope::One(StreamId(1)));
    assert_eq!(request.budget, Some(4));

    let response = service.call(request).unwrap();
    assert_eq!(response.draws, 4, "budget override reached the engine");
    assert!(response.streams().iter().all(|&s| s == StreamId(1)), "scope respected");

    let encoded = response.to_json().to_string();
    let decoded = venus::api::QueryResponse::from_json_str(&encoded).unwrap();
    assert_eq!(decoded.frame_indices(), response.frame_indices());
    assert_eq!(decoded.cache, response.cache);
    service.shutdown();
}
