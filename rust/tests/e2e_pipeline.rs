//! End-to-end integration: synthetic stream → threaded ingestion pipeline
//! (real backend embedding through the `EmbedBackend` trait) →
//! hierarchical memory → query stage → retrieval quality + serving loop,
//! all against planted ground truth.  Runs on the default backend — the
//! self-contained native MEM unless a pjrt build finds artifacts — shared
//! process-wide through `backend::shared_default`.

use std::sync::Arc;

use venus::util::sync::{ranks, OrderedRwLock};

use venus::api::{ApiError, Priority, QueryRequest};
use venus::backend::{self, EmbedBackend};
use venus::cloud::SelectionStats;
use venus::config::VenusConfig;
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::ingest::Pipeline;
use venus::memory::{Hierarchy, InMemoryRaw, MemoryFabric};
use venus::server::Service;
use venus::video::synth::{SynthConfig, VideoSynth};
use venus::video::workload::{DatasetPreset, WorkloadGen};

fn build_synth(duration_s: f64, seed: u64) -> VideoSynth {
    let be = backend::shared_default().expect("default backend");
    let codes = be.concept_codes().unwrap();
    let patch = be.model().patch;
    VideoSynth::new(
        SynthConfig { duration_s, seed, ..Default::default() },
        codes,
        patch,
    )
}

fn ingest_all(
    synth: &VideoSynth,
    cfg: &VenusConfig,
) -> (Arc<OrderedRwLock<Hierarchy>>, venus::ingest::IngestStats) {
    let be = backend::shared_default().unwrap();
    let d = be.model().d_embed;
    let memory = Arc::new(OrderedRwLock::new(
        ranks::shard(0),
        Hierarchy::new(&cfg.memory, d, Box::new(InMemoryRaw::new(synth.config().frame_size)))
            .unwrap(),
    ));
    let engine = EmbedEngine::new(be, cfg.ingest.aux_models).unwrap();
    let mut pipe =
        Pipeline::new(&cfg.ingest, synth.config().fps, engine, Arc::clone(&memory)).unwrap();
    for i in 0..synth.total_frames() {
        pipe.push_frame(i, &synth.frame(i)).unwrap();
    }
    let stats = pipe.finish().unwrap();
    (memory, stats)
}

#[test]
fn pipeline_builds_sparse_consistent_memory() {
    let synth = build_synth(40.0, 7);
    let (memory, stats) = ingest_all(&synth, &VenusConfig::default());
    let mem = memory.read();

    assert_eq!(stats.frames, synth.total_frames());
    assert_eq!(stats.embedded, mem.len());
    assert!(stats.partitions >= 2, "got {} partitions", stats.partitions);
    // sparsity: far fewer indexed frames than raw frames (the paper's
    // real-time-ingestion enabler)
    assert!(
        mem.sparsity() > 3.0,
        "sparsity {} (clusters {} / frames {})",
        mem.sparsity(),
        mem.len(),
        stats.frames
    );
    mem.check_invariants().unwrap();

    // conservation: every raw frame belongs to exactly one cluster
    let mut all: Vec<u64> = mem
        .records()
        .iter()
        .flat_map(|r| r.members.iter().cloned())
        .collect();
    all.sort_unstable();
    assert_eq!(all, (0..stats.frames).collect::<Vec<_>>());
}

#[test]
fn query_retrieves_evidence_frames() {
    let synth = build_synth(60.0, 8);
    let cfg = VenusConfig::default();
    let (memory, _) = ingest_all(&synth, &cfg);

    let queries =
        WorkloadGen::new(3, DatasetPreset::VideoMmeShort).generate(synth.script(), 12);

    let mut qe = QueryEngine::over_memory(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&memory),
        cfg.retrieval.clone(),
        11,
    );

    let mut covered = 0usize;
    for q in &queries {
        let out = qe
            .retrieve_with(&q.text, RetrievalMode::FixedSampling(32))
            .unwrap();
        let st = SelectionStats::compute(
            q,
            synth.script(),
            &out.selection.frame_indices(),
            4,
        );
        if st.coverage > 0.0 {
            covered += 1;
        }
    }
    // the MEM is constructed to align planted concepts; the large majority
    // of queries must retrieve at least one evidence frame
    assert!(
        covered * 10 >= queries.len() * 7,
        "only {covered}/{} queries retrieved evidence",
        queries.len()
    );
}

#[test]
fn akr_adapts_draws_to_query_type() {
    let synth = build_synth(90.0, 9);
    let cfg = VenusConfig::default();
    let (memory, _) = ingest_all(&synth, &cfg);

    let queries =
        WorkloadGen::new(5, DatasetPreset::VideoMmeShort).generate(synth.script(), 30);
    let mut qe = QueryEngine::over_memory(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&memory),
        cfg.retrieval.clone(),
        13,
    );

    // AKR must adapt: draw counts vary across queries, every run either
    // clears θ or exhausts n_max, and budgets stay within [1, n_max].
    // (The localized-vs-dispersed ordering itself is unit-tested with
    // controlled distributions in retrieval::akr; on real noisy
    // embeddings the workload's evidence-span geometry confounds it.)
    let mut draw_counts = Vec::new();
    for q in &queries {
        let out = qe.retrieve_with(&q.text, RetrievalMode::Akr).unwrap();
        assert!(out.draws >= 1 && out.draws <= cfg.retrieval.n_max);
        draw_counts.push(out.draws);
    }
    let min = *draw_counts.iter().min().unwrap();
    let max = *draw_counts.iter().max().unwrap();
    assert!(
        max > min,
        "AKR should adapt its budget across query types (all runs used {min} draws)"
    );
    // and the average should undercut the fixed budget — the Fig. 11 claim
    let mean = draw_counts.iter().sum::<usize>() as f64 / draw_counts.len() as f64;
    assert!(
        mean < cfg.retrieval.n_max as f64,
        "mean draws {mean} vs n_max {}",
        cfg.retrieval.n_max
    );
}

#[test]
fn serving_loop_completes_batch_with_conservation() {
    let synth = build_synth(30.0, 10);
    let mut cfg = VenusConfig::default();
    cfg.server.workers = 2;
    let (memory, _) = ingest_all(&synth, &cfg);
    let fabric = Arc::new(MemoryFabric::single(Arc::clone(&memory)));

    let service = Service::start(&cfg, Arc::clone(&fabric), 21).unwrap();
    let queries =
        WorkloadGen::new(6, DatasetPreset::VideoMmeShort).generate(synth.script(), 16);
    let mut receivers = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        // mixed-priority typed traffic
        let priority = if i % 2 == 0 { Priority::Interactive } else { Priority::Batch };
        let request = QueryRequest::new(&q.text).priority(priority);
        receivers.push(service.submit_request(request).expect("queue should accept"));
    }
    let mut ok = 0;
    for rx in receivers {
        let res = rx.recv().unwrap().unwrap();
        assert!(!res.evidence.is_empty());
        assert_eq!(res.evidence.len(), res.frame_indices().len());
        assert!(res.total_s() > 0.0);
        ok += 1;
    }
    assert_eq!(ok, queries.len());
    assert!(service.metrics.conserved_after_drain());

    // replay the same texts: every one is already cached, so every
    // response must report a cache hit and skip the edge hot path
    for q in &queries {
        let warm = service.call(QueryRequest::new(&q.text)).unwrap();
        assert!(warm.cache.is_hit(), "warm repeat must hit the query cache");
        assert_eq!(warm.edge.search_s + warm.edge.select_s, 0.0);
    }
    assert!(service.cache.stats().hits() >= queries.len() as u64);

    let snap = service.shutdown();
    assert_eq!(snap.completed(), 2 * queries.len() as u64);
    assert!(snap.interactive.completed > 0 && snap.batch.completed > 0);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.shutdown, 0);
    assert_eq!(snap.deadline_shed(), 0);
    // tail percentiles populated and ordered
    assert!(snap.total_p50_s.is_some());
    assert!(snap.total_p50_s <= snap.total_p95_s);
    assert!(snap.total_p95_s <= snap.total_p99_s);
}

#[test]
fn queries_succeed_while_ingestion_is_live() {
    // concurrency property: the query path reads the shared memory while
    // the pipeline's embed pool is still inserting — no deadlock, no
    // invariant violation, and late queries see a larger index.  With the
    // rank-ordered RwLock'd hierarchy the readers only exclude the writer for the
    // narrow score+select window.
    let synth = build_synth(40.0, 31);
    let cfg = VenusConfig::default();
    let be = backend::shared_default().unwrap();
    let d = be.model().d_embed;
    let memory = Arc::new(OrderedRwLock::new(
        ranks::shard(0),
        Hierarchy::new(
            &cfg.memory,
            d,
            Box::new(InMemoryRaw::new(synth.config().frame_size)),
        )
        .unwrap(),
    ));
    let engine = EmbedEngine::new(be, cfg.ingest.aux_models).unwrap();
    let mut pipe =
        Pipeline::new(&cfg.ingest, synth.config().fps, engine, Arc::clone(&memory)).unwrap();

    let mut qe = QueryEngine::over_memory(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&memory),
        cfg.retrieval.clone(),
        17,
    );

    let mut sizes = Vec::new();
    for i in 0..synth.total_frames() {
        pipe.push_frame(i, &synth.frame(i)).unwrap();
        if i % 100 == 99 {
            // give the async embed pool a beat to drain, then query live
            std::thread::sleep(std::time::Duration::from_millis(150));
            let out = qe
                .retrieve_with("what is happening with concept01", RetrievalMode::Akr)
                .unwrap();
            let len = memory.read().len();
            sizes.push(len);
            // selection only references archived frames
            let ingested = memory.read().frames_ingested();
            assert!(out.selection.frames.iter().all(|f| f.idx < ingested));
        }
    }
    pipe.finish().unwrap();
    memory.read().check_invariants().unwrap();
    // the index grew while we were querying (mid-stream, not just at end)
    assert!(
        sizes.iter().any(|&s| s > 0),
        "index never visible mid-stream: {sizes:?}"
    );
    assert!(
        memory.read().len() >= *sizes.last().unwrap(),
        "{sizes:?}"
    );
}

#[test]
fn embed_engine_pads_odd_batches_consistently() {
    // 5 frames through batch-8 chunking must equal per-frame batch-1
    let mut engine = EmbedEngine::default_backend(false).unwrap();
    let synth = build_synth(10.0, 33);
    let frames: Vec<_> = (0..5).map(|i| synth.frame(i * 7)).collect();
    let refs: Vec<&venus::video::frame::Frame> = frames.iter().collect();
    let batched = engine.embed_index_frames(&refs).unwrap();
    assert_eq!(batched.len(), 5);
    for (f, want) in frames.iter().zip(&batched) {
        let one = engine.embed_index_frames(&[f]).unwrap();
        let d = one[0]
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-4, "padded batch diverged from batch-1: {d}");
    }
}

#[test]
fn admission_control_rejects_per_lane_on_overflow() {
    let synth = build_synth(20.0, 12);
    let mut cfg = VenusConfig::default();
    cfg.server.workers = 1;
    cfg.api.batch_depth = Some(2);
    cfg.api.interactive_depth = Some(64);
    let (memory, _) = ingest_all(&synth, &cfg);
    let fabric = Arc::new(MemoryFabric::single(Arc::clone(&memory)));

    let service = Service::start(&cfg, Arc::clone(&fabric), 23).unwrap();
    // flood the batch lane: far more than its depth; some must be
    // rejected, none lost — and the interactive lane stays open
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..40 {
        let request = QueryRequest::new(format!("query number {i} about concept01"))
            .priority(Priority::Batch);
        match service.submit_request(request) {
            Ok(rx) => accepted.push(rx),
            Err(ApiError::Rejected { lane }) => {
                assert_eq!(lane, Priority::Batch);
                rejected += 1;
            }
            Err(e) => panic!("live service must only reject on overflow, got {e}"),
        }
    }
    // the full batch lane never blocks an interactive submission
    let interactive = service
        .submit_request(QueryRequest::new("urgent question about concept01"))
        .expect("interactive lane has room");
    for rx in accepted {
        let _ = rx.recv().unwrap();
    }
    interactive.recv().unwrap().unwrap();
    assert!(rejected > 0, "batch depth 2 must reject under flood");
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.rejected(), rejected);
    assert_eq!(snap.batch.rejected, rejected);
    assert_eq!(snap.interactive.rejected, 0);
    assert_eq!(snap.shutdown, 0, "no shutdown races in a live flood");
}
