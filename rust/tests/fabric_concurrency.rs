//! Concurrent fabric property test: K camera streams ingest through one
//! shared embed pool while queries run against `One` and `All` scopes.
//!
//! Properties under concurrency:
//!   * per-stream isolation — a `One(s)`-scoped selection never cites
//!     another stream's frames, and every shard's records reference only
//!     that shard's stream;
//!   * safety — every retrieval succeeds mid-ingest (no deadlock, no
//!     panic, no missing-frame error), selections reference only
//!     already-archived frames;
//!   * post-drain consistency — `check_invariants` holds on every shard
//!     and the `All` scope sees the union of the shards.

use std::sync::Arc;

use venus::backend::{self, EmbedBackend};
use venus::config::VenusConfig;
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::ingest::{EmbedPool, Pipeline};
use venus::memory::{
    MemoryFabric, RawStore, StreamId, StreamScope, SynthBackedRaw,
};
use venus::video::synth::{SynthConfig, VideoSynth};

const STREAMS: usize = 3;
const DURATION_S: f64 = 25.0;

fn build_streams() -> Vec<Arc<VideoSynth>> {
    let be = backend::shared_default().expect("default backend");
    let codes = be.concept_codes().unwrap();
    let patch = be.model().patch;
    (0..STREAMS)
        .map(|i| {
            Arc::new(VideoSynth::new(
                SynthConfig {
                    duration_s: DURATION_S,
                    seed: 0xfab + i as u64 * 101,
                    ..Default::default()
                },
                codes.clone(),
                patch,
            ))
        })
        .collect()
}

#[test]
fn streams_ingest_while_scoped_queries_run() {
    let cfg = VenusConfig::default();
    let be = backend::shared_default().unwrap();
    let d = be.model().d_embed;

    let synths = build_streams();
    let raws: Vec<Box<dyn RawStore>> = synths
        .iter()
        .map(|s| Box::new(SynthBackedRaw::new(Arc::clone(s))) as Box<dyn RawStore>)
        .collect();
    let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d, raws).unwrap());
    let pool = EmbedPool::start(be, cfg.ingest.aux_models, 2, 64).unwrap();

    // K ingestion threads, one per camera, all feeding the shared pool
    let mut writers = Vec::new();
    for (i, synth) in synths.iter().enumerate() {
        let shard = Arc::clone(fabric.shard(StreamId(i as u16)).unwrap());
        let mut pipe =
            Pipeline::attach(&cfg.ingest, synth.config().fps, &pool, shard).unwrap();
        let synth = Arc::clone(synth);
        writers.push(std::thread::spawn(move || {
            for f in 0..synth.total_frames() {
                pipe.push_frame(f, &synth.frame(f)).unwrap();
            }
            pipe.finish().unwrap()
        }));
    }

    // query thread interleaves One- and All-scoped retrievals mid-ingest
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&fabric),
        cfg.retrieval.clone(),
        77,
    );
    for round in 0..12u64 {
        std::thread::sleep(std::time::Duration::from_millis(60));
        let scope = if round % 2 == 0 {
            StreamScope::One(StreamId((round % STREAMS as u64) as u16))
        } else {
            StreamScope::All
        };
        let mode = if round % 3 == 0 {
            RetrievalMode::Akr
        } else {
            RetrievalMode::FixedSampling(8)
        };
        let out = qe
            .retrieve_scoped_with("what happened with concept01", scope, mode)
            .unwrap();
        // isolation: One(s) cites only stream s; safety: only archived ids
        for f in &out.selection.frames {
            if let StreamScope::One(s) = scope {
                assert_eq!(f.stream, s, "round {round}: scope leak {f:?}");
            }
            let archived = fabric
                .shard(f.stream)
                .unwrap()
                .read()
                .frames_ingested();
            assert!(
                f.idx < archived,
                "round {round}: selection cites unarchived {f:?} (< {archived})"
            );
        }
    }

    let mut total_frames = 0u64;
    for w in writers {
        let stats = w.join().expect("ingest thread");
        assert!(stats.embedded > 0);
        total_frames += stats.frames;
    }
    pool.shutdown().unwrap();

    // post-drain: invariants on EVERY shard; records isolated per stream
    fabric.check_invariants().unwrap();
    assert_eq!(fabric.total_frames(), total_frames);
    for (i, shard) in fabric.shards().iter().enumerate() {
        let g = shard.read();
        assert!(!g.is_empty(), "shard {i} indexed nothing");
        for r in g.records() {
            assert_eq!(
                r.stream,
                StreamId(i as u16),
                "record in shard {i} cites {:?}",
                r.stream
            );
        }
    }

    // All scope sees the union of the shards
    let merged = qe.score_query("what happened with concept01").unwrap();
    assert_eq!(merged.len(), fabric.total_indexed());
    let out = qe
        .retrieve_scoped_with(
            "what happened with concept01",
            StreamScope::All,
            RetrievalMode::FixedSampling(48),
        )
        .unwrap();
    assert!(!out.selection.frames.is_empty());
}
