//! Live-ingest integration suite: cameras as wire clients against a real
//! TCP gateway with an [`IngestHub`].
//!
//! Covers the four contract points of DESIGN.md §Ingest-Wire:
//!   * **reconnect-with-resume** — killing a camera connection mid-batch
//!     and reconnecting loses nothing and duplicates nothing against a
//!     durable fabric: the server-authoritative `next_seq` arbitrates,
//!     and retrieval selections are bit-identical to an unfaulted run
//!     (before AND after a crash-recovery restart of the fabric);
//!   * **typed backpressure observed client-side** — `Dropped` verdicts
//!     under Interactive-lane pressure advance the watermark past the
//!     hole without archiving; `SlowDown` verdicts accept while pacing;
//!   * **protocol violations fail the connection, never the session** —
//!     stale leases, out-of-order batches, and oversized batches each
//!     get a typed error and a close, and the next `ingest_open` resumes
//!     exactly at the surviving watermark;
//!   * **ingest gauges on the wire** — the `stats` reply round-trips
//!     per-stream counters, freshness percentiles, and the embed pool's
//!     queue/coalescing gauges.

use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use venus::api::Priority;
use venus::config::{MemoryConfig, RetrievalConfig, VenusConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::ingest::IngestStats;
use venus::memory::{FrameId, InMemoryRaw, MemoryFabric, RawStore, StreamId, StreamScope};
use venus::net::wire::{
    read_frame, write_frame, Backpressure, Camera, ClientMsg, Gateway, IngestFrame, IngestHub,
    ServerMsg, WireClient, WireError, PROTOCOL_VERSION,
};
use venus::server::Service;
use venus::util::b64::encode_f32s;
use venus::video::frame::Frame;
use venus::video::synth::{SynthConfig, VideoSynth};

const SIZE: usize = 64;
const MAX: usize = 1 << 20;

/// Unique scratch dir, removed on drop (durable-fabric tests).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "venus-ingest-wire-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn embed_dim() -> usize {
    venus::embed::EmbedEngine::default_backend(false).unwrap().d_embed()
}

fn ram_fabric(streams: usize) -> Arc<MemoryFabric> {
    let raws: Vec<Box<dyn RawStore>> =
        (0..streams).map(|_| Box::new(InMemoryRaw::new(SIZE)) as Box<dyn RawStore>).collect();
    Arc::new(MemoryFabric::new(&MemoryConfig::default(), embed_dim(), raws).unwrap())
}

/// Service + hub + gateway over an ephemeral port.
fn hub_gateway(
    cfg: &VenusConfig,
    fabric: &Arc<MemoryFabric>,
    workers: usize,
) -> (Arc<Service>, Arc<IngestHub>, Gateway) {
    let service = Arc::new(Service::start(cfg, Arc::clone(fabric), 7).unwrap());
    let hub = Arc::new(
        IngestHub::new(cfg, Arc::clone(fabric), Arc::clone(&service.metrics), workers).unwrap(),
    );
    let gateway =
        Gateway::start_with(&cfg.wire, Arc::clone(&service), Some(Arc::clone(&hub))).unwrap();
    (service, hub, gateway)
}

/// Tear down in the durability-safe order: gateway first (no connection
/// can race new batches in), then the hub drain, then the service.
fn teardown(
    gateway: Gateway,
    hub: Arc<IngestHub>,
    service: Arc<Service>,
) -> Vec<(u16, IngestStats)> {
    gateway.shutdown();
    let stats = hub.finish_all().unwrap();
    drop(hub); // last hub handle: the embed pool drains and joins here
    let service = Arc::try_unwrap(service).ok().expect("gateway released its service handle");
    service.shutdown();
    stats
}

/// A hand-driven camera connection speaking the raw typed protocol, so
/// tests can violate it deliberately and die mid-batch.
struct RawCam {
    s: TcpStream,
}

impl RawCam {
    fn connect(addr: SocketAddr) -> Self {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut cam = Self { s };
        match cam.round_trip(&ClientMsg::Hello { version: PROTOCOL_VERSION }) {
            ServerMsg::HelloAck { .. } => cam,
            other => panic!("handshake failed: {other:?}"),
        }
    }

    fn send(&mut self, msg: &ClientMsg) {
        let mut w = &self.s;
        write_frame(&mut w, &msg.to_json(), MAX).unwrap();
    }

    fn round_trip(&mut self, msg: &ClientMsg) -> ServerMsg {
        self.send(msg);
        let mut r = &self.s;
        ServerMsg::from_json(&read_frame(&mut r, MAX).unwrap()).unwrap()
    }

    fn open(&mut self, stream: u16, fps: f64) -> u64 {
        match self.round_trip(&ClientMsg::IngestOpen { stream, frame_size: SIZE, fps }) {
            ServerMsg::IngestOpenAck { stream: sid, next_seq } => {
                assert_eq!(sid, stream);
                next_seq
            }
            other => panic!("ingest_open failed: {other:?}"),
        }
    }

    fn push(&mut self, stream: u16, frames: Vec<IngestFrame>) -> (u64, Backpressure) {
        match self.round_trip(&ClientMsg::IngestFrames { stream, frames }) {
            ServerMsg::IngestAck { stream: sid, high_watermark, backpressure } => {
                assert_eq!(sid, stream);
                (high_watermark, backpressure)
            }
            other => panic!("ingest_frames failed: {other:?}"),
        }
    }

    /// Push a batch the server must refuse; returns the typed message.
    fn push_refused(&mut self, stream: u16, frames: Vec<IngestFrame>) -> String {
        match self.round_trip(&ClientMsg::IngestFrames { stream, frames }) {
            ServerMsg::Error { error: WireError::Protocol(msg) } => msg,
            other => panic!("expected a typed protocol error, got {other:?}"),
        }
    }
}

fn wire_frame(seq: u64) -> IngestFrame {
    let f = Frame::filled(SIZE, [(seq % 8) as f32 / 8.0, 0.2, 0.2]);
    IngestFrame {
        seq,
        captured_unix_ms: venus::net::wire::ingest::unix_ms_now(),
        data_b64: encode_f32s(f.data()),
    }
}

fn batch(from: u64, n: u64) -> Vec<IngestFrame> {
    (from..from + n).map(wire_frame).collect()
}

/// Acceptance: the `stats` wire reply round-trips per-stream ingest
/// counters, capture→queryable freshness percentiles, and the shared
/// embed pool's coalescing gauges, live while cameras push.
#[test]
fn stats_reply_carries_ingest_gauges_and_freshness() {
    let fabric = ram_fabric(2);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    // seal a partition every 4 frames of stream time so freshness
    // samples appear while the cameras are still pushing
    cfg.ingest.max_partition_s = 0.5;
    let (service, hub, gateway) = hub_gateway(&cfg, &fabric, 2);
    let addr = gateway.local_addr();

    let mut cams: Vec<RawCam> = (0..2u16).map(|_| RawCam::connect(addr)).collect();
    for (sid, cam) in cams.iter_mut().enumerate() {
        assert_eq!(cam.open(sid as u16, 8.0), 0);
    }
    for b in 0..4u64 {
        for (sid, cam) in cams.iter_mut().enumerate() {
            let (hw, bp) = cam.push(sid as u16, batch(b * 8, 8));
            assert_eq!(hw, (b + 1) * 8);
            assert_eq!(bp, Backpressure::None, "unloaded server must not push back");
        }
    }

    // poll the WIRE stats reply (exercising the snapshot's JSON
    // round-trip) until the async embed pool makes partitions queryable
    let mut client = WireClient::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let snap = client.stats().unwrap();
        let ing = snap.ingest.expect("hub-enabled gateway always reports ingest gauges");
        assert_eq!(ing.streams.len(), 2);
        for s in &ing.streams {
            assert_eq!(s.accepted, 32);
            assert_eq!(s.acked, 32);
            assert_eq!(s.dropped, 0);
        }
        if ing.pool_batches > 0 && ing.streams.iter().all(|s| s.freshness_p50_ms.is_some()) {
            for s in &ing.streams {
                let (p50, p95) = (s.freshness_p50_ms.unwrap(), s.freshness_p95_ms.unwrap());
                assert!(p50 >= 0.0 && p95 >= p50, "freshness tails out of order: {s:?}");
            }
            assert!(ing.pool_mean_batch_clusters > 0.0);
            break;
        }
        assert!(Instant::now() < deadline, "freshness gauges never converged: {ing:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
    drop(client);
    drop(cams);

    let stats = teardown(gateway, hub, service);
    assert_eq!(stats.len(), 2);
    for (_, s) in &stats {
        assert_eq!(s.frames, 32);
    }
}

/// Acceptance: backpressure verdicts reach the client typed.  Under
/// Interactive-lane pressure the `drop` policy sheds whole batches and
/// advances the watermark past the hole (nothing archived); the
/// `slowdown` policy accepts while telling the camera to pace down.
#[test]
fn backpressure_verdicts_reach_the_client() {
    // drop policy
    let fabric = ram_fabric(1);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    cfg.ingest.drop_policy = "drop".into();
    cfg.ingest.yield_queue_depth = 0;
    cfg.ingest.staleness_bound_ms = 3_600_000; // keep the starvation guard out
    let (service, hub, gateway) = hub_gateway(&cfg, &fabric, 1);

    let mut cam = RawCam::connect(gateway.local_addr());
    assert_eq!(cam.open(0, 8.0), 0);
    let (hw, bp) = cam.push(0, batch(0, 4));
    assert_eq!((hw, bp), (4, Backpressure::None));

    // a queued interactive query flips the admission controller
    service.metrics.on_accepted(Priority::Interactive);
    let (hw, bp) = cam.push(0, batch(4, 4));
    assert_eq!(hw, 8, "the watermark advances past the hole");
    assert_eq!(bp, Backpressure::Dropped { from_seq: 4, count: 4 });
    service.metrics.on_dequeued(Priority::Interactive);

    // lane drained: admitted again, resuming AFTER the hole
    let (hw, bp) = cam.push(0, batch(8, 4));
    assert_eq!((hw, bp), (12, Backpressure::None));
    assert_eq!(
        fabric.shard(StreamId(0)).unwrap().read().frames_ingested(),
        8,
        "dropped frames must never reach the archive"
    );
    drop(cam);
    let stats = teardown(gateway, hub, service);
    assert_eq!(stats[0].1.frames, 8);

    // slowdown policy: same pressure, nothing lost
    let fabric = ram_fabric(1);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    cfg.ingest.drop_policy = "slowdown".into();
    cfg.ingest.yield_queue_depth = 0;
    cfg.ingest.slowdown_ms = 25;
    cfg.ingest.staleness_bound_ms = 3_600_000;
    let (service, hub, gateway) = hub_gateway(&cfg, &fabric, 1);
    let mut cam = RawCam::connect(gateway.local_addr());
    assert_eq!(cam.open(0, 8.0), 0);
    service.metrics.on_accepted(Priority::Interactive);
    let (hw, bp) = cam.push(0, batch(0, 4));
    assert_eq!(hw, 4);
    assert_eq!(bp, Backpressure::SlowDown { delay_ms: 25 });
    service.metrics.on_dequeued(Priority::Interactive);
    assert_eq!(
        fabric.shard(StreamId(0)).unwrap().read().frames_ingested(),
        4,
        "slowdown accepts every frame"
    );
    drop(cam);
    let stats = teardown(gateway, hub, service);
    assert_eq!(stats[0].1.frames, 4);
}

/// Acceptance: a protocol violation kills exactly one connection with a
/// typed error; the stream session and its watermark survive for the
/// next `ingest_open`.
#[test]
fn violations_fail_the_connection_never_the_session() {
    let fabric = ram_fabric(1);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    cfg.ingest.max_batch_frames = 8;
    let (service, hub, gateway) = hub_gateway(&cfg, &fabric, 1);
    let addr = gateway.local_addr();

    let mut a = RawCam::connect(addr);
    assert_eq!(a.open(0, 8.0), 0);
    a.push(0, batch(0, 4));

    // a reconnecting camera steals the lease and resumes at the watermark
    let mut b = RawCam::connect(addr);
    assert_eq!(b.open(0, 8.0), 4);
    // ...so the stale connection's next push is refused (and closed)
    let msg = a.push_refused(0, batch(4, 4));
    assert!(msg.contains("stale"), "{msg}");

    // out-of-order seq against the live watermark
    let msg = b.push_refused(0, batch(20, 4));
    assert!(msg.contains("out-of-order"), "{msg}");

    // oversized batch (b is dead; fresh connection, fresh open)
    let mut c = RawCam::connect(addr);
    assert_eq!(c.open(0, 8.0), 4, "the watermark survived both violations");
    let msg = c.push_refused(0, batch(4, 9));
    assert!(msg.contains("max_batch_frames"), "{msg}");

    // and after three failed connections the stream still ingests
    let mut d = RawCam::connect(addr);
    assert_eq!(d.open(0, 8.0), 4);
    let (hw, _) = d.push(0, batch(4, 4));
    assert_eq!(hw, 8);
    drop((a, b, c, d));

    assert!(gateway.stats().protocol_errors >= 3);
    let stats = teardown(gateway, hub, service);
    assert_eq!(stats[0].1.frames, 8, "exactly the accepted frames, no ghosts");
}

/// Frames for seqs `from..from+n` with pixels from the shared synth (the
/// exact payloads `Camera` itself would send).
fn synth_batch(synth: &VideoSynth, from: u64, n: u64) -> Vec<IngestFrame> {
    let total = synth.total_frames().max(1);
    (from..from + n)
        .map(|seq| IngestFrame {
            seq,
            captured_unix_ms: venus::net::wire::ingest::unix_ms_now(),
            data_b64: encode_f32s(synth.frame(seq % total).data()),
        })
        .collect()
}

/// The selection fingerprint used for bit-identity claims: frame ids,
/// score bits, and draw counts across the retrieval modes.
fn selection_matrix(fabric: &Arc<MemoryFabric>) -> Vec<(Vec<FrameId>, Vec<u32>, usize)> {
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(false).unwrap(),
        Arc::clone(fabric),
        RetrievalConfig::default(),
        11,
    );
    let mut out = Vec::new();
    for mode in [RetrievalMode::Akr, RetrievalMode::FixedSampling(8), RetrievalMode::TopK(4)] {
        let o = qe
            .retrieve_scoped_with("what happened with concept01", StreamScope::All, mode)
            .unwrap();
        out.push((
            o.selection.frames.clone(),
            o.frame_scores.iter().map(|s| s.to_bits()).collect(),
            o.draws,
        ));
    }
    out
}

fn test_synth() -> Arc<VideoSynth> {
    let be = venus::backend::shared_default().unwrap();
    let cfg = SynthConfig { duration_s: 6.0, seed: 3, ..Default::default() };
    Arc::new(VideoSynth::new(cfg, be.concept_codes().unwrap(), be.model().patch))
}

/// Acceptance (tentpole): kill a camera connection mid-batch against a
/// DURABLE fabric, reconnect, and resume from the server-authoritative
/// watermark.  No frame is duplicated or lost — the faulted run's
/// retrieval selections are bit-identical to an unfaulted control run,
/// and stay bit-identical after a flush + crash-recovery restart.
#[test]
fn camera_reconnect_is_exactly_once_against_a_durable_fabric() {
    let synth = test_synth();
    let frames = synth.total_frames();
    assert!(frames >= 32, "need room for a mid-stream fault, got {frames}");
    let d = embed_dim();
    let mem_cfg = MemoryConfig::default();
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    // one camera per run, one pool worker, a single partition sealed at
    // finish: every source of cross-run reordering is pinned down, so
    // bit-identity is the only acceptable outcome
    let fps = 240.0;

    let run = |tmp: &TempDir, fault: bool| -> (Arc<MemoryFabric>, u64) {
        let fabric =
            Arc::new(MemoryFabric::open(&mem_cfg, d, 1, SIZE, &tmp.0).unwrap());
        let (service, hub, gateway) = hub_gateway(&cfg, &fabric, 1);
        let addr = gateway.local_addr();

        let mut camera = Camera::new(addr.to_string(), 0, Arc::clone(&synth));
        camera.fps = fps;
        if fault {
            // push the first stretch by hand, then die mid-batch: the
            // last envelope is written but the ack is never read, so the
            // CLIENT cannot know whether it was applied
            let mut cam = RawCam::connect(addr);
            assert_eq!(cam.open(0, fps), 0);
            cam.push(0, synth_batch(&synth, 0, 8));
            cam.push(0, synth_batch(&synth, 8, 8));
            cam.send(&ClientMsg::IngestFrames { stream: 0, frames: synth_batch(&synth, 16, 8) });
            drop(cam); // hard kill, ack abandoned in flight
            // the envelope was fully flushed before the close, so the
            // server WILL apply it — wait for that so the resume point
            // is pinned and both runs push frames `24..48` identically
            let deadline = Instant::now() + Duration::from_secs(10);
            while hub.snapshot().streams[0].acked < 24 {
                assert!(Instant::now() < deadline, "abandoned batch never applied");
                std::thread::sleep(Duration::from_millis(10));
            }
            // `Camera::frames` counts from the watermark at first open
            camera.frames = frames - 24;
        }
        let report = camera.run().unwrap();
        assert_eq!(report.watermark, frames);
        assert_eq!(report.dropped, 0);

        let snap = hub.snapshot();
        assert_eq!(snap.streams[0].accepted, frames, "every frame applied exactly once");
        assert_eq!(snap.streams[0].acked, frames);

        let stats = teardown(gateway, hub, service);
        assert_eq!(stats[0].1.frames, frames);
        let ingested = fabric.shard(StreamId(0)).unwrap().read().frames_ingested();
        (fabric, ingested)
    };

    let control_tmp = TempDir::new("control");
    let (control, control_ingested) = run(&control_tmp, false);
    let faulted_tmp = TempDir::new("faulted");
    let (faulted, faulted_ingested) = run(&faulted_tmp, true);
    assert_eq!(control_ingested, frames);
    assert_eq!(faulted_ingested, frames, "reconnect neither lost nor duplicated frames");

    let expected = selection_matrix(&control);
    assert_eq!(
        expected,
        selection_matrix(&faulted),
        "a mid-batch fault must be invisible to retrieval"
    );

    // durable means durable: flush, drop every handle, recover from disk
    faulted.flush().unwrap();
    drop(control);
    let faulted = Arc::try_unwrap(faulted).ok().expect("all fabric handles released");
    drop(faulted);
    let recovered =
        Arc::new(MemoryFabric::recover(&mem_cfg, d, 1, SIZE, &faulted_tmp.0).unwrap());
    assert_eq!(recovered.total_frames(), frames);
    recovered.check_invariants().unwrap();
    assert_eq!(
        expected,
        selection_matrix(&recovered),
        "recovery must reproduce the faulted run's selections byte-for-byte"
    );
}
