//! Crash-recovery integration suite for the durable tiered memory.
//!
//! Covers the three contract points of DESIGN.md §Storage:
//!   * **recovery watermark** — killing a fabric mid-ingest (drop without
//!     flush == crash) recovers exactly to the last sealed watermark;
//!     a flushed WAL tail survives in full;
//!   * **restart equivalence** — after `MemoryFabric::recover`, One- and
//!     All-scope selections are byte-identical to the pre-restart fabric
//!     (and to a pure-RAM fabric with the same content), across every
//!     retrieval mode;
//!   * **eviction under live queries** — with a hot budget forcing
//!     demotion during a sustained ingest, resident hot bytes stay under
//!     budget, queries keep succeeding mid-eviction, and selections over
//!     evicted (cold) records still fetch their frames from disk.

use std::path::PathBuf;
use std::sync::Arc;

use venus::config::{MemoryConfig, RetrievalConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::memory::{ClusterRecord, FrameId, MemoryFabric, StreamId, StreamScope};
use venus::util::rng::Pcg64;
use venus::video::frame::Frame;

/// Unique scratch dir, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "venus-recovery-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn unit(rng: &mut Pcg64, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    venus::util::l2_normalize(&mut v);
    v
}

/// Fill one shard with `n` 4-frame clusters of seeded random embeddings.
fn fill_shard(fabric: &MemoryFabric, sid: u16, n: u64, d: usize, seed: u64) {
    let shard = fabric.shard(StreamId(sid)).unwrap();
    let mut g = shard.write();
    let mut rng = Pcg64::seeded(seed);
    for c in 0..n {
        for f in c * 4..(c + 1) * 4 {
            g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        let v = unit(&mut rng, d);
        g.insert(
            &v,
            ClusterRecord {
                stream: StreamId(sid),
                scene_id: c as usize,
                centroid_frame: c * 4,
                members: (c * 4..(c + 1) * 4).collect(),
            },
        )
        .unwrap();
    }
}

#[test]
fn crash_recovers_to_last_sealed_watermark() {
    let tmp = TempDir::new("sealed-wm");
    let cfg = MemoryConfig { segment_records: 4, ..Default::default() };
    let d = 8usize;
    {
        let fabric = MemoryFabric::open(&cfg, d, 2, 8, &tmp.0).unwrap();
        for sid in 0..2 {
            fill_shard(&fabric, sid, 10, d, 0xbeef + sid as u64);
        }
        assert_eq!(
            fabric.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 10), (StreamId(1), 10)]
        );
        // drop WITHOUT flush: everything since the last seal is lost —
        // 10 inserts = two sealed segments of 4 + a 2-record WAL tail
    }
    let fabric = MemoryFabric::recover(&cfg, d, 2, 8, &tmp.0).unwrap();
    assert_eq!(
        fabric.watermarks(StreamScope::All).unwrap(),
        vec![(StreamId(0), 8), (StreamId(1), 8)],
        "recovery lands on the last sealed watermark"
    );
    // the frame log is eager: every archived frame survived the crash
    assert_eq!(fabric.total_frames(), 80);
    fabric.check_invariants().unwrap();

    // extend past the lost tail, FLUSH this time: the tail must survive
    {
        let shard = fabric.shard(StreamId(0)).unwrap();
        let mut g = shard.write();
        let mut rng = Pcg64::seeded(1);
        for c in 8..10u64 {
            let v = unit(&mut rng, d);
            g.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 4,
                    members: (c * 4..(c + 1) * 4).collect(),
                },
            )
            .unwrap();
        }
    }
    fabric.flush().unwrap();
    drop(fabric);
    let fabric = MemoryFabric::recover(&cfg, d, 2, 8, &tmp.0).unwrap();
    assert_eq!(
        fabric.watermarks(StreamScope::One(StreamId(0))).unwrap(),
        vec![(StreamId(0), 10)],
        "flushed WAL tail survives the restart"
    );
    fabric.check_invariants().unwrap();
}

/// The full mode × scope matrix a serving deployment exercises.
fn query_matrix(
    qe: &mut QueryEngine,
) -> Vec<(Vec<FrameId>, Vec<u32>, usize)> {
    let mut out = Vec::new();
    for scope in [
        StreamScope::One(StreamId(0)),
        StreamScope::One(StreamId(1)),
        StreamScope::All,
    ] {
        for mode in [
            RetrievalMode::Akr,
            RetrievalMode::FixedSampling(8),
            RetrievalMode::TopK(4),
        ] {
            let outcome = qe
                .retrieve_scoped_with("what happened with concept01", scope, mode)
                .unwrap();
            out.push((
                outcome.selection.frames.clone(),
                outcome.frame_scores.iter().map(|s| s.to_bits()).collect(),
                outcome.draws,
            ));
        }
    }
    out
}

#[test]
fn restart_equivalence_selections_are_byte_identical() {
    let tmp = TempDir::new("equiv");
    let engine = EmbedEngine::default_backend(false).unwrap();
    let d = engine.d_embed();
    let cfg = MemoryConfig { segment_records: 6, ..Default::default() };

    // durable fabric, 2 streams × 16 clusters, flushed
    let fabric = Arc::new(MemoryFabric::open(&cfg, d, 2, 8, &tmp.0).unwrap());
    for sid in 0..2 {
        fill_shard(&fabric, sid, 16, d, 0x5eed + sid as u64);
    }
    fabric.flush().unwrap();

    // a pure-RAM twin with identical content: durable layering must not
    // perturb selections when everything fits hot
    let ram_cfg = MemoryConfig::default();
    let raws: Vec<Box<dyn venus::memory::RawStore>> = (0..2)
        .map(|_| Box::new(venus::memory::InMemoryRaw::new(8)) as Box<dyn venus::memory::RawStore>)
        .collect();
    let ram = Arc::new(MemoryFabric::new(&ram_cfg, d, raws).unwrap());
    for sid in 0..2 {
        fill_shard(&ram, sid, 16, d, 0x5eed + sid as u64);
    }

    let mut qe =
        QueryEngine::new(engine, Arc::clone(&fabric), RetrievalConfig::default(), 11);
    let before = query_matrix(&mut qe);

    let mut qe_ram = QueryEngine::new(
        EmbedEngine::default_backend(false).unwrap(),
        Arc::clone(&ram),
        RetrievalConfig::default(),
        11,
    );
    assert_eq!(
        before,
        query_matrix(&mut qe_ram),
        "durable (all-hot) and pure-RAM fabrics must select identically"
    );

    // restart #1: unbounded budget — every sealed span is promoted back
    // into RAM, and the matrix replays byte-for-byte
    drop(qe);
    drop(fabric);
    let recovered = Arc::new(MemoryFabric::recover(&cfg, d, 2, 8, &tmp.0).unwrap());
    assert_eq!(
        recovered.watermarks(StreamScope::All).unwrap(),
        vec![(StreamId(0), 16), (StreamId(1), 16)],
        "per-shard ingest watermarks restored"
    );
    let mut qe2 = QueryEngine::new(
        EmbedEngine::default_backend(false).unwrap(),
        Arc::clone(&recovered),
        RetrievalConfig::default(),
        11,
    );
    let after = query_matrix(&mut qe2);
    assert_eq!(
        before, after,
        "recovered fabric must reproduce selections byte-for-byte"
    );
    recovered.check_invariants().unwrap();
    let ts = recovered.tier_stats();
    assert_eq!(
        ts.cold_records, 0,
        "unbounded recovery promotes sealed spans back to RAM: {ts:?}"
    );
    assert_eq!(ts.hot_records, 32);

    // restart #2: a budget that only fits the WAL tail — sealed spans
    // stay demoted, so the same matrix now runs through the cold-tier
    // per-segment scan path and must STILL be byte-identical
    drop(qe2);
    drop(recovered);
    let tail_budget = 4 * (d * 4 + std::mem::size_of::<ClusterRecord>() + 4 * 8);
    let cold_cfg = MemoryConfig { hot_budget_bytes: tail_budget, ..cfg.clone() };
    let cold_fabric = Arc::new(MemoryFabric::recover(&cold_cfg, d, 2, 8, &tmp.0).unwrap());
    let mut qe3 = QueryEngine::new(
        EmbedEngine::default_backend(false).unwrap(),
        Arc::clone(&cold_fabric),
        RetrievalConfig::default(),
        11,
    );
    assert_eq!(
        before,
        query_matrix(&mut qe3),
        "cold-tier scoring must preserve the exact Eq. 4–5 distribution"
    );
    cold_fabric.check_invariants().unwrap();
    let ts = cold_fabric.tier_stats();
    assert!(ts.cold_records > 0, "budgeted recovery keeps sealed spans cold: {ts:?}");
    assert!(ts.cold_hits + ts.cold_misses > 0, "queries scanned cold segments");
    assert!(ts.hot_bytes <= 2 * tail_budget, "per-shard hot tiers stay bounded: {ts:?}");
}

#[test]
fn eviction_under_live_queries_stays_bounded_and_correct() {
    let tmp = TempDir::new("evict-live");
    let engine = EmbedEngine::default_backend(false).unwrap();
    let d = engine.d_embed();
    // budget ≈ 24 records of vectors+metadata: forces steady demotion
    let budget = 24 * (d * 4 + std::mem::size_of::<ClusterRecord>() + 2 * 8);
    let cfg = MemoryConfig {
        segment_records: 8,
        hot_budget_bytes: budget,
        cold_cache_segments: 2,
        ..Default::default()
    };
    let fabric = Arc::new(MemoryFabric::open(&cfg, d, 1, 8, &tmp.0).unwrap());

    let writer_fabric = Arc::clone(&fabric);
    let writer = std::thread::spawn(move || {
        let shard = writer_fabric.shard(StreamId(0)).unwrap();
        let mut rng = Pcg64::seeded(77);
        for c in 0..150u64 {
            {
                let mut g = shard.write();
                for f in c * 2..(c + 1) * 2 {
                    g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
                }
                let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                venus::util::l2_normalize(&mut v);
                g.insert(
                    &v,
                    ClusterRecord {
                        stream: StreamId(0),
                        scene_id: c as usize,
                        centroid_frame: c * 2,
                        members: vec![c * 2, c * 2 + 1],
                    },
                )
                .unwrap();
            }
            // the acceptance bound: resident hot bytes never exceed the
            // budget, at any point of the sustained ingest
            let hot = shard.read().hot_bytes();
            assert!(hot <= budget, "hot tier {hot} B over the {budget} B budget");
            std::thread::yield_now();
        }
    });

    let mut qe =
        QueryEngine::new(engine, Arc::clone(&fabric), RetrievalConfig::default(), 3);
    for i in 0..20 {
        let mode = if i % 2 == 0 {
            RetrievalMode::Akr
        } else {
            RetrievalMode::FixedSampling(6)
        };
        let out = qe
            .retrieve_scoped_with("what happened with concept01", StreamScope::All, mode)
            .unwrap();
        let archived = fabric.shard(StreamId(0)).unwrap().read().frames_ingested();
        assert!(
            out.selection.frames.iter().all(|f| f.idx < archived),
            "selection referenced an unarchived frame"
        );
    }
    writer.join().unwrap();
    fabric.check_invariants().unwrap();

    let ts = fabric.tier_stats();
    assert!(ts.evictions > 0 && ts.cold_segments > 0, "eviction never ran: {ts:?}");
    assert!(ts.hot_bytes <= budget, "post-drain hot tier over budget: {ts:?}");
    assert_eq!(ts.cold_records + ts.hot_records, 150);

    // queries spanning evicted (cold) records still succeed end-to-end:
    // the full 150-record distribution is visible and evicted frames
    // fetch from the on-disk frame log
    let out = qe
        .retrieve_scoped_with(
            "what happened with concept01",
            StreamScope::All,
            RetrievalMode::FixedSampling(32),
        )
        .unwrap();
    assert!(!out.selection.frames.is_empty());
    let cold_frame = FrameId::new(StreamId(0), 0); // record 0 is long demoted
    assert!(fabric.fetch_frame(cold_frame).is_ok(), "cold frame must fetch from disk");
    let ts = fabric.tier_stats();
    assert!(ts.cold_hits + ts.cold_misses > 0, "queries never touched the cold tier");
}
