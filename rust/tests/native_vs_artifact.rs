//! Cross-backend parity suite (`pjrt` builds only): the native pure-Rust
//! backend vs the AOT-compiled XLA artifacts, driven through the same
//! [`EmbedBackend`] trait.
//!
//! Weights are generated independently on each side (jax threefry vs PCG64
//! — statistically matched, not bit-identical; see `backend::native`), so
//! parity is asserted at three levels:
//!   1. **kernel-exact** — Eq. 1 scene features and the Eq. 4–5 similarity
//!      epilogue are deterministic functions of their inputs and must
//!      match to float tolerance across backends;
//!   2. **golden-exact** — the artifact path must reproduce the Python
//!      reference numerics recorded at `make artifacts` time (the
//!      HLO-text round-trip is lossless);
//!   3. **behavioral** — both backends must rank concept-planted frames
//!      above non-planted ones for the same query (the property the
//!      retrieval stage depends on).
//!
//! Tests skip (pass trivially with a note) when no artifact directory is
//! present or the linked `xla` crate is the offline stub — `cargo test
//! --features pjrt` stays green on artifact-less checkouts while still
//! type-checking the whole PJRT surface.

#![cfg(feature = "pjrt")]

use venus::backend::{EmbedBackend, NativeBackend, NativeConfig};
use venus::embed::Tokenizer;
use venus::runtime::Runtime;
use venus::util::rng::Pcg64;
use venus::util::{dot, l2_normalize, softmax_temp};
use venus::video::frame::Frame;

fn runtime() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping pjrt parity test: {e:#}");
            None
        }
    }
}

fn native() -> NativeBackend {
    NativeBackend::new(NativeConfig::default())
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn read_f32(rt: &Runtime, key: &str) -> Vec<f32> {
    rt.manifest().read_f32_file(key).unwrap().0
}

// -------------------------------------------------------------------
// 1. kernel-exact parity
// -------------------------------------------------------------------

#[test]
fn scene_features_agree_across_backends() {
    let Some(rt) = runtime() else { return };
    let nat = native();
    let size = rt.model().img_size;
    let mut rng = Pcg64::seeded(41);
    let mut flat = Vec::new();
    for _ in 0..8 {
        let mut f = Frame::new(size);
        for v in f.data_mut() {
            *v = rng.f32();
        }
        flat.extend_from_slice(f.data());
    }
    let artifact = EmbedBackend::scene_features(&rt, &flat, 8).unwrap();
    let native_rows = nat.scene_features(&flat, 8).unwrap();
    for (a, b) in artifact.iter().zip(&native_rows) {
        let d = max_abs_diff(a, b);
        assert!(d < 1e-4, "scene features diverged across backends: {d}");
    }
}

#[test]
fn similarity_epilogue_agrees_across_backends() {
    let Some(rt) = runtime() else { return };
    let nat = native();
    let m = rt.model().clone();
    let mut rng = Pcg64::seeded(43);
    let n = 640;
    let mut index = vec![0.0f32; m.sim_rows * m.d_embed];
    for r in 0..n {
        let row = &mut index[r * m.d_embed..(r + 1) * m.d_embed];
        for x in row.iter_mut() {
            *x = rng.normal();
        }
        l2_normalize(row);
    }
    let q = index[5 * m.d_embed..6 * m.d_embed].to_vec();
    for tau in [0.05f32, 0.07, 0.2, 1.0] {
        let (a_scores, a_probs) = EmbedBackend::similarity(&rt, &q, &index, n, tau).unwrap();
        let (n_scores, n_probs) = nat.similarity(&q, &index, n, tau).unwrap();
        assert!(max_abs_diff(&a_scores, &n_scores) < 1e-4, "tau={tau}: scores");
        assert!(max_abs_diff(&a_probs, &n_probs) < 1e-4, "tau={tau}: probs");
        // and both agree with the scalar epilogue
        let mut host = vec![0.0f32; n];
        softmax_temp(&a_scores, tau, &mut host);
        assert!(max_abs_diff(&host, &a_probs) < 1e-4, "tau={tau}: host recompute");
    }
}

// -------------------------------------------------------------------
// 2. golden-exact: artifact path vs recorded Python reference numerics
// -------------------------------------------------------------------

#[test]
fn golden_image_embedding_matches_python() {
    let Some(rt) = runtime() else { return };
    let img = read_f32(&rt, "golden_image");
    let want = read_f32(&rt, "golden_image_emb");
    let got = rt.embed_image(&img, 1).unwrap();
    let d = max_abs_diff(&got[0], &want);
    assert!(d < 5e-4, "image embedding diverged: max|Δ| = {d}");
}

#[test]
fn golden_text_embedding_matches_python() {
    let Some(rt) = runtime() else { return };
    let tokens = rt.manifest().read_i32_file("golden_tokens").unwrap().0;
    let want = read_f32(&rt, "golden_text_emb");
    let got = rt.embed_text(&tokens).unwrap();
    let d = max_abs_diff(&got, &want);
    assert!(d < 5e-4, "text embedding diverged: max|Δ| = {d}");
}

#[test]
fn golden_scene_features_match_python() {
    let Some(rt) = runtime() else { return };
    let img = read_f32(&rt, "golden_image");
    let want = read_f32(&rt, "golden_scene_feat");
    // scene_feat artifact is batch-8: tile the golden image
    let mut batch = Vec::with_capacity(img.len() * 8);
    for _ in 0..8 {
        batch.extend_from_slice(&img);
    }
    let got = rt.scene_features(&batch, 8).unwrap();
    for row in &got {
        let d = max_abs_diff(row, &want);
        assert!(d < 1e-4, "scene features diverged: max|Δ| = {d}");
    }
}

// -------------------------------------------------------------------
// 3. behavioral parity: both backends must support the retrieval oracle
// -------------------------------------------------------------------

#[test]
fn both_backends_rank_planted_concepts_for_the_same_query() {
    let Some(rt) = runtime() else { return };
    let nat = native();
    let query_text = "what happened with concept07";
    let target = 7usize;

    for (name, be) in [
        ("pjrt", &rt as &dyn EmbedBackend),
        ("native", &nat as &dyn EmbedBackend),
    ] {
        let m = be.model().clone();
        let codes = be.concept_codes().unwrap();
        let tok = Tokenizer::from_model(be.model());
        let mut rng = Pcg64::seeded(47);
        let mut flat = Vec::new();
        for i in 0..8u64 {
            let mut f = Frame::new(m.img_size);
            for v in f.data_mut() {
                *v = rng.f32();
            }
            let c = if i < 4 { target } else { (target + 1 + i as usize) % codes.len() };
            f.blend_block(0, 0, m.patch, &codes[c], 0.8);
            flat.extend_from_slice(f.data());
        }
        let embs = be.embed_image(&flat, 8).unwrap();
        let qvec = be.embed_text(&tok.tokenize(query_text)).unwrap();
        let sims: Vec<f32> = embs.iter().map(|e| dot(&qvec, e)).collect();
        let min_match = sims[..4].iter().cloned().fold(f32::INFINITY, f32::min);
        let max_other = sims[4..].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(
            min_match > max_other + 0.2,
            "{name}: planted-concept ranking margin too small: {sims:?}"
        );
    }
}
