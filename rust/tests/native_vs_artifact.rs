//! Cross-validation between native-Rust fast paths and the AOT artifacts:
//! the Eq. 1 features and the retrieval softmax must agree between the
//! hand-written Rust used on the streaming hot path and the Pallas/XLA
//! kernels, and the baseline score oracle must rank like the real MEM.

use venus::embed::EmbedEngine;
use venus::features::frame_features;
use venus::runtime::Runtime;
use venus::util::rng::Pcg64;
use venus::util::softmax_temp;
use venus::video::frame::Frame;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

#[test]
fn native_scene_features_match_pallas_kernel() {
    let rt = runtime();
    let mut rng = Pcg64::seeded(41);
    let size = rt.model().img_size;
    let mut frames = Vec::new();
    let mut flat = Vec::new();
    for _ in 0..8 {
        let mut f = Frame::new(size);
        for v in f.data_mut() {
            *v = rng.f32();
        }
        flat.extend_from_slice(f.data());
        frames.push(f);
    }
    let artifact = rt.scene_features(&flat, 8).unwrap();
    for (f, want) in frames.iter().zip(&artifact) {
        let got = frame_features(f);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want) {
            assert!((a - b).abs() < 1e-4, "native {a} vs artifact {b}");
        }
    }
}

#[test]
fn native_softmax_matches_similarity_kernel() {
    let rt = runtime();
    let m = rt.model();
    let mut rng = Pcg64::seeded(43);
    let n = 640;
    let mut index = vec![0.0f32; m.sim_rows * m.d_embed];
    for r in 0..n {
        let row = &mut index[r * m.d_embed..(r + 1) * m.d_embed];
        for x in row.iter_mut() {
            *x = rng.normal();
        }
        venus::util::l2_normalize(row);
    }
    let q = index[5 * m.d_embed..6 * m.d_embed].to_vec();
    for tau in [0.05f32, 0.07, 0.2, 1.0] {
        let (scores, probs) = rt.similarity(&q, &index, n, tau).unwrap();
        let mut native = vec![0.0f32; n];
        softmax_temp(&scores, tau, &mut native);
        for (a, b) in native.iter().zip(&probs) {
            assert!((a - b).abs() < 1e-4, "tau={tau}: native {a} vs kernel {b}");
        }
    }
}

/// The baseline oracle must rank frames the same way the real MEM does:
/// frames showing the queried concept above frames that don't.
#[test]
fn oracle_ranking_consistent_with_real_encoder() {
    let rt = runtime();
    let codes = rt.concept_codes().unwrap();
    let patch = rt.model().patch;
    let mut engine = EmbedEngine::new(runtime(), false).unwrap();

    let mut rng = Pcg64::seeded(47);
    let size = rt.model().img_size;
    let target = 7usize;

    // 8 frames: 4 with the target concept planted, 4 with others
    let mut frames = Vec::new();
    for i in 0..8u64 {
        let mut f = Frame::new(size);
        for v in f.data_mut() {
            *v = rng.f32();
        }
        let c = if i < 4 { target } else { (target + 1 + i as usize) % codes.len() };
        f.blend_block(0, 0, patch, &codes[c], 0.8);
        frames.push(f);
    }
    let refs: Vec<&Frame> = frames.iter().collect();
    let embs = engine.embed_index_frames(&refs).unwrap();
    let qvec = engine
        .embed_query(&format!("what happened with concept{target:02}"))
        .unwrap();

    let sims: Vec<f32> = embs.iter().map(|e| venus::util::dot(&qvec, e)).collect();
    let min_match = sims[..4].iter().cloned().fold(f32::INFINITY, f32::min);
    let max_other = sims[4..].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert!(
        min_match > max_other,
        "real encoder must separate match vs non-match: {sims:?}"
    );
    // and the margin is large, as the oracle's MATCH_MEAN/OTHER_MEAN assume
    assert!(
        min_match - max_other > 0.2,
        "margin too small for the oracle model: {sims:?}"
    );
}
