//! End-to-end query-tracing suite: span-tree completeness over a live
//! wire gateway, trace JSON round-trip tolerance, head-sampling
//! honored, slow-ring bounds under flood, and the disabled-path
//! overhead guard (opt-in via `OBS_OVERHEAD_ASSERT=1` — wall-clock
//! bounds are hostile to loaded CI machines).

use std::sync::Arc;
use std::time::Instant;

use venus::api::QueryRequest;
use venus::config::{MemoryConfig, ObsConfig, VenusConfig};
use venus::memory::{ClusterRecord, Hierarchy, InMemoryRaw, MemoryFabric, RawStore, StreamId};
use venus::net::wire::{Gateway, WireClient};
use venus::obs::{stage, Trace, Tracer};
use venus::server::Service;
use venus::util::json::Json;
use venus::util::rng::Pcg64;
use venus::util::sync::OrderedRwLock;
use venus::video::frame::Frame;

/// A deterministic fabric: `streams` shards, each with `clusters`
/// random-unit-vector records over 4-frame clusters (same construction
/// as the wire_protocol suite).
fn seeded_fabric(d: usize, streams: usize, clusters: u64, seed: u64) -> Arc<MemoryFabric> {
    let raws: Vec<Box<dyn RawStore>> =
        (0..streams).map(|_| Box::new(InMemoryRaw::new(8)) as Box<dyn RawStore>).collect();
    let fabric = Arc::new(MemoryFabric::new(&MemoryConfig::default(), d, raws).unwrap());
    let mut rng = Pcg64::seeded(seed);
    for sid in 0..streams as u16 {
        let shard: &Arc<OrderedRwLock<Hierarchy>> = fabric.shard(StreamId(sid)).unwrap();
        let mut g = shard.write();
        for c in 0..clusters {
            for f in c * 4..(c + 1) * 4 {
                g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
            }
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            g.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(sid),
                    scene_id: c as usize,
                    centroid_frame: c * 4,
                    members: (c * 4..(c + 1) * 4).collect(),
                },
            )
            .unwrap();
        }
    }
    fabric
}

fn embed_dim() -> usize {
    venus::embed::EmbedEngine::default_backend(false).unwrap().d_embed()
}

/// Acceptance: a traced wire query's span tree carries every pipeline
/// stage (gateway I/O included), top-level spans tile the timeline
/// without overlap, the stage sum lands within 10% of the reported
/// total, and the tree survives a JSON round trip.
#[test]
fn wire_query_trace_is_complete_and_sums_to_the_total() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 2, 8, 0x0b5e);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    let service = Arc::new(Service::start(&cfg, fabric, 17).unwrap());
    let gateway = Gateway::start(&cfg.wire, Arc::clone(&service)).unwrap();
    let mut client = WireClient::connect(gateway.local_addr()).unwrap();

    let resp = client
        .query(QueryRequest::new("what happened with concept02"))
        .unwrap()
        .unwrap();
    let id = resp.trace_id.expect("default config samples every query");

    // fetched over the SAME connection: the handler appended the
    // gateway/write span before it could read this trace request
    let t = client.trace(id).unwrap().expect("trace still in the ring");
    assert_eq!(t.id, id);
    assert_eq!(t.kind, "query");
    for s in [
        stage::GATEWAY_READ,
        stage::QUEUE_WAIT,
        stage::CACHE_PROBE,
        stage::EMBED,
        stage::SCORE,
        stage::SELECT,
        stage::FETCH,
        stage::UPLOAD,
        stage::VLM,
        stage::GATEWAY_WRITE,
    ] {
        assert!(t.span(s).is_some(), "stage '{s}' missing from {t:?}");
    }

    // top-level spans tile the timeline: sorted by start, each begins no
    // earlier than the previous one ended (±500 µs clock-read slack)
    let mut tops: Vec<_> = t.spans.iter().filter(|s| !s.is_child()).collect();
    tops.sort_by_key(|s| s.start_us);
    for w in tops.windows(2) {
        let prev_end = w[0].start_us + w[0].dur_us;
        assert!(
            w[1].start_us + 500 >= prev_end,
            "'{}' (ends {prev_end}) overlaps '{}' (starts {})",
            w[0].stage,
            w[1].stage,
            w[1].start_us
        );
    }

    // the --trace contract: stage sum within 10% of the reported total
    let sum = t.stage_sum_us() as f64;
    let total = t.total_us as f64;
    assert!(total > 0.0);
    assert!(
        (sum - total).abs() <= total * 0.10,
        "stage sum {sum}us vs total {total}us drifts past 10%: {}",
        t.render()
    );
    // ...and the wire response's own clock agrees with the trace
    let resp_total_us = resp.total_s() * 1e6;
    assert!(
        (total - resp_total_us).abs() <= resp_total_us * 0.10 + 2_000.0,
        "trace total {total}us vs response total {resp_total_us}us"
    );

    // scoring counters ride the span tree
    let score = t.span(stage::SCORE).unwrap();
    assert!(score.counters.contains_key("rows"), "{score:?}");
    assert!(score.counters.contains_key("shards"), "{score:?}");

    // JSON round trip is lossless for a live trace
    let wire_json = t.to_json().to_string();
    let back = Trace::from_json(&Json::parse(&wire_json).unwrap()).unwrap();
    assert_eq!(back, t);

    drop(client);
    gateway.shutdown();
    Arc::try_unwrap(service).ok().expect("service released").shutdown();
}

/// The telemetry surface: `metrics_text` renders Prometheus text with
/// span-derived histograms, and the recent/slow trace listings answer
/// over the same connection.
#[test]
fn metrics_text_and_trace_listings_over_the_wire() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 6, 0x3e7a);
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    let service = Arc::new(Service::start(&cfg, fabric, 5).unwrap());
    let gateway = Gateway::start(&cfg.wire, Arc::clone(&service)).unwrap();
    let mut client = WireClient::connect(gateway.local_addr()).unwrap();

    for i in 0..3 {
        client.query(QueryRequest::new(format!("metrics warmup query {i}"))).unwrap().unwrap();
    }

    let text = client.metrics_text().unwrap();
    for needle in [
        "venus_uptime_seconds",
        "venus_throughput_qps",
        "venus_lane_queries_total",
        "venus_traces_finished_total",
        "venus_stage_duration_seconds_bucket",
        "stage=\"embed\"",
        "stage=\"total\"",
    ] {
        assert!(text.contains(needle), "metrics text missing '{needle}':\n{text}");
    }

    let recent = client.recent_traces(10, false).unwrap();
    assert!(recent.len() >= 3, "3 queries traced, got {}", recent.len());
    assert!(recent.iter().all(|t| t.kind == "query"));
    // newest first
    assert!(recent[0].label.contains("query 2"), "{}", recent[0].label);
    // the slow listing answers (contents depend on machine speed)
    let _slow = client.recent_traces(10, true).unwrap();
    // an unknown id is an empty listing, not an error
    assert!(client.trace(venus::obs::TraceId(0xdead_beef)).unwrap().is_none());

    drop(client);
    gateway.shutdown();
    Arc::try_unwrap(service).ok().expect("service released").shutdown();
}

/// Head sampling: `trace_sample_n = 2` traces every other query;
/// `trace_sample_n = 0` mints nothing and echoes no ids.
#[test]
fn sampling_rate_is_honored_and_disabled_means_no_ids() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 6, 0x5a11);
    let mut cfg = VenusConfig::default();
    cfg.obs.trace_sample_n = 2;
    let service = Service::start(&cfg, Arc::clone(&fabric), 3).unwrap();
    let sampled: Vec<bool> = (0..4)
        .map(|i| {
            let r = service.call(QueryRequest::new(format!("sampling probe {i}"))).unwrap();
            r.trace_id.is_some()
        })
        .collect();
    assert_eq!(sampled, vec![true, false, true, false], "1-in-2 head sampling");
    assert_eq!(service.tracer.counts().finished, 2);
    service.shutdown();

    let mut cfg = VenusConfig::default();
    cfg.obs.trace_sample_n = 0;
    let service = Service::start(&cfg, fabric, 3).unwrap();
    for i in 0..4 {
        let r = service.call(QueryRequest::new(format!("untraced probe {i}"))).unwrap();
        assert!(r.trace_id.is_none(), "tracing disabled must echo no id");
    }
    assert_eq!(service.tracer.counts().finished, 0);
    assert!(service.tracer.recent(usize::MAX).is_empty());
    service.shutdown();
}

/// Flood: with a 1 ms slow bar every query is "slow", yet both rings
/// hold their configured bounds and the monotone counters keep the
/// full tally.
#[test]
fn slow_ring_stays_bounded_under_flood() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 6, 0xf10d);
    let mut cfg = VenusConfig::default();
    cfg.obs.slow_query_ms = 1; // the modeled VLM stage alone is >100 ms
    cfg.obs.trace_ring = 8;
    cfg.obs.slow_ring = 4;
    let service = Service::start(&cfg, fabric, 9).unwrap();
    for i in 0..20 {
        service.call(QueryRequest::new(format!("flood query number {i}"))).unwrap();
    }
    assert_eq!(service.tracer.recent(usize::MAX).len(), 8, "completed ring bounded");
    assert_eq!(service.tracer.slow_recent(usize::MAX).len(), 4, "slow ring bounded");
    let c = service.tracer.counts();
    assert_eq!(c.finished, 20);
    assert_eq!(c.slow, 20, "every query crossed the 1 ms bar");
    service.shutdown();
}

/// Opt-in overhead guard (`OBS_OVERHEAD_ASSERT=1`): the disabled-path
/// mint is a single branch — no atomics, no allocation — so even ten
/// million calls must finish in well under a second.
#[test]
fn disabled_path_mint_overhead_guard() {
    if std::env::var("OBS_OVERHEAD_ASSERT").ok().as_deref() != Some("1") {
        return; // wall-clock assertions are opt-in (loaded CI machines)
    }
    let tracer = Tracer::new(&ObsConfig {
        trace_sample_n: 0,
        ..ObsConfig::default()
    });
    let t0 = Instant::now();
    let mut minted = 0u64;
    for _ in 0..10_000_000u64 {
        if tracer.mint("query", "overhead probe").is_some() {
            minted += 1;
        }
    }
    assert_eq!(minted, 0);
    let elapsed = t0.elapsed();
    assert!(
        elapsed.as_millis() < 1000,
        "10M disabled mints took {elapsed:?} — the disabled path must stay branch-cheap"
    );
}
