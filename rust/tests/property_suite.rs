//! Property-based test suite (hand-rolled generators over PCG seeds —
//! proptest is unavailable offline).  Each property is exercised across
//! many random instances; failures print the seed for replay.

use venus::config::{IngestConfig, MemoryConfig, VenusConfig};
use venus::features::{frame_features, scene_score, ChannelWeights};
use venus::ingest::{PartitionClusterer, SceneSegmenter};
use venus::memory::{
    ClusterRecord, FlatIndex, Hierarchy, InMemoryRaw, IvfIndex, Metric, StreamId, VectorIndex,
};
use venus::retrieval::{akr_retrieve, sample_retrieve, softmax_probs, topk_retrieve};
use venus::util::json::Json;
use venus::util::rng::Pcg64;
use venus::video::frame::Frame;
use venus::video::synth::{SceneScript, SynthConfig};
use venus::video::workload::{DatasetPreset, WorkloadGen};

fn random_memory(seed: u64) -> (Hierarchy, usize) {
    let mut rng = Pcg64::seeded(seed);
    let n_clusters = rng.range(2, 64);
    let mut h = Hierarchy::new(
        &MemoryConfig::default(),
        16,
        Box::new(InMemoryRaw::new(8)),
    )
    .unwrap();
    let mut frame_id = 0u64;
    let mut records = Vec::new();
    for c in 0..n_clusters {
        let len = rng.range(1, 12) as u64;
        let members: Vec<u64> = (frame_id..frame_id + len).collect();
        for &m in &members {
            h.archive_frame(m, &Frame::filled(8, [0.5; 3])).unwrap();
        }
        records.push((c, members.clone()));
        frame_id += len;
    }
    for (c, members) in records {
        let mut v: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        venus::util::l2_normalize(&mut v);
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: c,
                centroid_frame: members[0],
                members,
            },
        )
        .unwrap();
    }
    (h, n_clusters)
}

#[test]
fn prop_sampling_invariants() {
    for seed in 0..40u64 {
        let (mem, n) = random_memory(1000 + seed);
        let mut rng = Pcg64::seeded(seed);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let budget = rng.range(1, 64);
        let tau = 0.05 + rng.f32() * 2.0;
        let sel = sample_retrieve(&mem, &scores, tau, budget, &mut rng);
        // draws == budget; probs sum to 1; frames valid & sorted-unique
        assert_eq!(sel.drawn_indices.len(), budget, "seed {seed}");
        let psum: f32 = sel.probs.iter().sum();
        assert!((psum - 1.0).abs() < 1e-4, "seed {seed}: prob sum {psum}");
        assert!(sel.frames.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
        for &f in &sel.frames {
            assert_eq!(f.stream, StreamId(0), "seed {seed}");
            assert!(f.idx < mem.frames_ingested(), "seed {seed}");
        }
        // every selected frame belongs to a drawn cluster
        for &f in &sel.frames {
            let owner = mem
                .records()
                .iter()
                .position(|r| r.members.binary_search(&f.idx).is_ok())
                .unwrap();
            assert!(sel.drawn_indices.contains(&owner), "seed {seed}");
        }
    }
}

#[test]
fn prop_akr_bounds_and_mass() {
    for seed in 0..40u64 {
        let (mem, n) = random_memory(2000 + seed);
        let mut rng = Pcg64::seeded(seed);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 0.5).collect();
        let theta = 0.5 + rng.f64() * 0.45;
        let beta = 1.0 + rng.f64() * 4.0;
        let n_max = rng.range(4, 64);
        let out = akr_retrieve(&mem, &scores, 0.2, theta, beta, n_max, &mut rng);
        assert!(out.draws <= n_max, "seed {seed}");
        assert!(out.draws >= 1, "seed {seed}");
        // termination condition: mass ≥ θ or the cap was hit or the floor
        // bound exceeded the cap
        assert!(
            out.mass >= theta || out.draws == n_max,
            "seed {seed}: draws {} mass {:.3} θ {theta:.3}",
            out.draws,
            out.mass
        );
        assert!(out.selection.frames.len() <= out.draws, "seed {seed}");
    }
}

#[test]
fn prop_topk_returns_true_maxima() {
    for seed in 0..40u64 {
        let (mem, n) = random_memory(3000 + seed);
        let mut rng = Pcg64::seeded(seed);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let k = rng.range(1, n + 1);
        let sel = topk_retrieve(&mem, &scores, k);
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let kth = sorted[k - 1];
        for &idx in &sel.drawn_indices {
            assert!(scores[idx] >= kth - 1e-6, "seed {seed}");
        }
    }
}

#[test]
fn prop_softmax_normalized_and_monotone() {
    for seed in 0..60u64 {
        let mut rng = Pcg64::seeded(4000 + seed);
        let n = rng.range(1, 512);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let tau = 0.02 + rng.f32() * 3.0;
        let p = softmax_probs(&scores, tau);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-4, "seed {seed}");
        // order preservation
        for i in 0..n {
            for j in 0..n {
                if scores[i] > scores[j] {
                    assert!(p[i] >= p[j] - 1e-6, "seed {seed}");
                }
            }
        }
    }
}

#[test]
fn prop_flat_and_ivf_score_all_agree() {
    // score_all is exact for both indexes, under every metric
    for metric in [Metric::Cosine, Metric::InnerProduct, Metric::L2] {
        for seed in 0..10u64 {
            let mut rng = Pcg64::seeded(5000 + seed);
            let dim = 8 + rng.range(0, 24);
            let n = rng.range(10, 600);
            let mut flat = FlatIndex::new(dim, metric);
            let mut ivf = IvfIndex::new(dim, metric, 8, 4);
            for _ in 0..n {
                let v: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                flat.insert(&v).unwrap();
                ivf.insert(&v).unwrap();
            }
            let q: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            flat.score_all(&q, &mut a);
            ivf.score_all(&q, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5, "{metric:?} seed {seed}");
            }
        }
    }
}

#[test]
fn prop_l2_self_query_round_trips() {
    // under the L2 metric every stored vector is its own nearest neighbor
    // (score 0), trained or not — the metric-dispatch regression test
    for seed in 0..6u64 {
        let mut rng = Pcg64::seeded(5600 + seed);
        let dim = 4 + rng.range(0, 12);
        let n = 280 + rng.range(0, 200); // crosses the IVF training threshold
        let mut flat = FlatIndex::new(dim, Metric::L2);
        let mut ivf = IvfIndex::new(dim, Metric::L2, 8, 8); // probe all
        for _ in 0..n {
            let scale = 0.5 + rng.f32() * 10.0; // mixed magnitudes
            let v: Vec<f32> = (0..dim).map(|_| rng.normal() * scale).collect();
            flat.insert(&v).unwrap();
            ivf.insert(&v).unwrap();
        }
        for probe in [0usize, n / 2, n - 1] {
            let q = flat.vector(probe).to_vec();
            assert_eq!(flat.search(&q, 1)[0].id, probe, "flat seed {seed}");
            let hit = ivf.search(&q, 1)[0];
            assert_eq!(hit.id, probe, "ivf seed {seed}");
            assert!(hit.score.abs() < 1e-6, "seed {seed}: self-distance {}", hit.score);
        }
    }
}

#[test]
fn prop_segmentation_partitions_tile_any_stream() {
    for seed in 0..8u64 {
        let cfg = SynthConfig {
            duration_s: 20.0 + (seed as f64) * 7.0,
            seed: 6000 + seed,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(seed);
        let codes: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..192).map(|_| rng.f32()).collect())
            .collect();
        let synth = venus::video::synth::VideoSynth::new(cfg, codes, 8);
        let mut seg = SceneSegmenter::new(&IngestConfig::default(), 8.0);
        let mut parts = Vec::new();
        for i in 0..synth.total_frames() {
            if let Some(p) = seg.push(&synth.frame(i)) {
                parts.push(p);
            }
        }
        parts.extend(seg.finish());
        assert_eq!(parts[0].start, 0, "seed {seed}");
        for w in parts.windows(2) {
            assert_eq!(w[0].end, w[1].start, "seed {seed}");
        }
        assert_eq!(parts.last().unwrap().end, synth.total_frames(), "seed {seed}");
    }
}

#[test]
fn prop_clustering_conserves_frames() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::seeded(7000 + seed);
        let n = rng.range(5, 120) as u64;
        let threshold = 0.02 + rng.f32() * 0.3;
        let mut c = PartitionClusterer::new(threshold);
        for i in 0..n {
            let v = rng.f32();
            c.push(i, &Frame::filled(16, [v, v * 0.5, 1.0 - v]));
        }
        let clusters = c.finish();
        let mut all: Vec<u64> = clusters.iter().flat_map(|c| c.members.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "seed {seed}");
        for cl in &clusters {
            assert!(cl.members.contains(&cl.centroid_id), "seed {seed}");
        }
    }
}

#[test]
fn prop_scene_score_is_a_semimetric() {
    let w = ChannelWeights::default();
    for seed in 0..20u64 {
        let mut rng = Pcg64::seeded(8000 + seed);
        let mk = |rng: &mut Pcg64| {
            let mut f = Frame::new(64);
            for v in f.data_mut() {
                *v = rng.f32();
            }
            frame_features(&f)
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        assert!(scene_score(&a, &a, w).abs() < 1e-6, "seed {seed}");
        let ab = scene_score(&a, &b, w);
        let ba = scene_score(&b, &a, w);
        assert!((ab - ba).abs() < 1e-6, "seed {seed}: symmetry");
        assert!(ab >= 0.0, "seed {seed}: non-negative");
    }
}

#[test]
fn prop_workload_evidence_within_stream() {
    for seed in 0..12u64 {
        let cfg = SynthConfig {
            duration_s: 60.0 + seed as f64 * 30.0,
            seed: 9000 + seed,
            ..Default::default()
        };
        let script = SceneScript::generate(&cfg, 24);
        for preset in DatasetPreset::all() {
            let qs = WorkloadGen::new(seed, preset).generate(&script, 15);
            for q in qs {
                for (s, e) in q.evidence {
                    assert!(s < e && e <= script.total_frames, "seed {seed}");
                }
                assert!(q.distractor_concepts.len() < q.n_options);
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.next_u64() % 1000)),
            4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.range(0, 5) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..50u64 {
        let mut rng = Pcg64::seeded(seed);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

#[test]
fn prop_config_defaults_survive_partial_toml() {
    // any subset of keys set → the rest are defaults, validation holds
    let keys = [
        ("retrieval.tau", "0.15"),
        ("retrieval.budget", "24"),
        ("ingest.embed_batch", "8"),
        ("net.bandwidth_mbps", "50.0"),
        ("cloud.answer_tokens", "12"),
        ("server.workers", "3"),
    ];
    for mask in 0u32..(1 << keys.len()) {
        let mut text = String::new();
        for (i, (k, v)) in keys.iter().enumerate() {
            if mask & (1 << i) != 0 {
                let (section, key) = k.split_once('.').unwrap();
                text.push_str(&format!("[{section}]\n{key} = {v}\n"));
            }
        }
        // group duplicate section headers: our parser rejects duplicate
        // keys only, duplicate section headers are fine to re-open
        let cfg = VenusConfig::from_toml(&text).unwrap_or_else(|e| {
            panic!("mask {mask:b}: {e}\n{text}")
        });
        cfg.validate().unwrap();
    }
}

#[test]
fn query_on_empty_memory_yields_empty_selection() {
    let mem = Hierarchy::new(
        &MemoryConfig::default(),
        16,
        Box::new(InMemoryRaw::new(8)),
    )
    .unwrap();
    let mut rng = Pcg64::seeded(1);
    let sel = sample_retrieve(&mem, &[], 0.2, 16, &mut rng);
    assert!(sel.frames.is_empty());
    let out = akr_retrieve(&mem, &[], 0.2, 0.9, 4.0, 16, &mut rng);
    assert!(out.selection.frames.is_empty());
}
