//! Backend contract goldens: every [`EmbedBackend`] implementation must
//! satisfy these numeric/structural properties.  Runs against the
//! process-default backend — the native MEM on default builds, the PJRT
//! artifact runtime when a pjrt build finds artifacts — so the contract
//! is enforced on whatever backend actually serves requests.
//!
//! (The byte-level Python-golden comparisons that used to live here apply
//! only to the artifact path and moved to the `pjrt`-gated parity suite in
//! `native_vs_artifact.rs`.)
//!
//! Honest caveat: on default builds the scene-feature and similarity
//! checks compare the native backend against the same host routines it is
//! built from, so they pin the *contract* (shapes, truncation, masking,
//! normalization) rather than independently re-deriving the numerics; the
//! independent cross-implementation comparison is the pjrt parity suite.

use std::sync::Arc;

use venus::backend::{shared_default, EmbedBackend};
use venus::embed::Tokenizer;
use venus::util::rng::Pcg64;
use venus::util::{dot, l2_normalize, softmax_temp};
use venus::video::frame::Frame;

fn backend() -> Arc<dyn EmbedBackend> {
    shared_default().expect("default backend must construct without artifacts")
}

fn noisy_frame(seed: u64, size: usize) -> Frame {
    let mut rng = Pcg64::seeded(seed);
    let mut f = Frame::new(size);
    for v in f.data_mut() {
        *v = rng.f32();
    }
    f
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn embeddings_are_unit_norm() {
    let be = backend();
    let f = noisy_frame(101, be.model().img_size);
    let emb = be.embed_image(f.data(), 1).unwrap();
    let norm: f32 = emb[0].iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4, "image norm = {norm}");

    let tok = Tokenizer::from_model(be.model());
    let q = be.embed_text(&tok.tokenize("when did concept05 happen")).unwrap();
    let norm: f32 = q.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4, "text norm = {norm}");
}

#[test]
fn batched_image_tower_consistent_across_batch_sizes() {
    let be = backend();
    let f = noisy_frame(102, be.model().img_size);
    let e1 = be.embed_image(f.data(), 1).unwrap()[0].clone();
    let mut b8 = Vec::new();
    for _ in 0..8 {
        b8.extend_from_slice(f.data());
    }
    let e8 = be.embed_image(&b8, 8).unwrap();
    for row in &e8 {
        let d = max_abs_diff(row, &e1);
        assert!(d < 1e-4, "batch-8 row diverged from batch-1: {d}");
    }
}

#[test]
fn embedding_is_deterministic_across_backend_instances() {
    // two independently-constructed, identically-configured native
    // backends must agree bit-for-bit (seeded weight generation); the
    // process-shared default must agree with them when it is native
    use venus::backend::{NativeBackend, NativeConfig};
    let a = NativeBackend::new(NativeConfig::default());
    let b = NativeBackend::new(NativeConfig::default());
    let f = noisy_frame(103, a.model().img_size);
    let ea = a.embed_image(f.data(), 1).unwrap();
    let eb = b.embed_image(f.data(), 1).unwrap();
    assert!(
        max_abs_diff(&ea[0], &eb[0]) < 1e-6,
        "two identically-configured backends must agree"
    );
    let shared = backend();
    if shared.name() == "native" && shared.model().img_size == a.model().img_size {
        let es = shared.embed_image(f.data(), 1).unwrap();
        assert!(max_abs_diff(&es[0], &ea[0]) < 1e-6, "shared default diverged");
    }
}

#[test]
fn similarity_kernel_matches_native_softmax() {
    let be = backend();
    let m = be.model().clone();
    // deterministic unit-norm index rows
    let mut rng = Pcg64::seeded(99);
    let n_valid = 700.min(m.sim_rows);
    let mut index = vec![0.0f32; m.sim_rows * m.d_embed];
    for r in 0..n_valid {
        let row = &mut index[r * m.d_embed..(r + 1) * m.d_embed];
        for x in row.iter_mut() {
            *x = rng.normal();
        }
        l2_normalize(row);
    }
    let query: Vec<f32> = index[3 * m.d_embed..4 * m.d_embed].to_vec();
    let tau = 0.1;
    let (scores, probs) = be.similarity(&query, &index, n_valid, tau).unwrap();
    assert_eq!(scores.len(), n_valid);
    // native recompute
    let mut want_scores = vec![0.0f32; n_valid];
    for r in 0..n_valid {
        want_scores[r] = dot(&query, &index[r * m.d_embed..(r + 1) * m.d_embed]);
    }
    let mut want_probs = vec![0.0f32; n_valid];
    softmax_temp(&want_scores, tau, &mut want_probs);
    assert!(max_abs_diff(&scores, &want_scores) < 1e-4);
    assert!(max_abs_diff(&probs, &want_probs) < 1e-4);
    // exact-match row must dominate
    let argmax = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax, 3);
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "probs sum {sum}");
}

#[test]
fn scene_features_match_native_frontend() {
    // Eq. 1 features from the backend must agree with the pure-Rust
    // perception front-end used on the streaming hot path.
    let be = backend();
    let size = be.model().img_size;
    let mut flat = Vec::new();
    let mut frames = Vec::new();
    for s in 0..4u64 {
        let f = noisy_frame(110 + s, size);
        flat.extend_from_slice(f.data());
        frames.push(f);
    }
    let got = be.scene_features(&flat, 4).unwrap();
    for (f, row) in frames.iter().zip(&got) {
        let want = venus::features::frame_features(f);
        let d = max_abs_diff(row, &want);
        assert!(d < 1e-4, "scene features diverged: {d}");
    }
}

#[test]
fn fused_entry_sharpens_planted_concept() {
    let be = backend();
    let m = be.model().clone();
    let codes = be.concept_codes().unwrap();

    // concept 5 planted strongly in the watermark patch
    let mut f = noisy_frame(120, m.img_size);
    f.blend_block(0, 0, m.patch, &codes[5], 0.9);
    let mut batch = Vec::new();
    for _ in 0..8 {
        batch.extend_from_slice(f.data());
    }
    let concept_token = (m.concept_token_base + 5) as i32;
    let mut aux = vec![0i32; 8 * m.seq_len];
    for b in 0..8 {
        aux[b * m.seq_len] = concept_token;
    }
    let fused = be.embed_fused(&batch, &aux, 8).unwrap();
    let plain = be.embed_image(&batch, 8).unwrap();
    // aux prompt must sharpen the planted concept's direction
    let dirs = be.concept_dirs().unwrap();
    let mut u = dirs[5].clone();
    l2_normalize(&mut u);
    let fu = dot(&fused[0], &u);
    let pl = dot(&plain[0], &u);
    assert!(
        fu > pl,
        "aux prompt should raise concept-5 alignment: fused {fu} vs plain {pl}"
    );
}

#[test]
fn cross_modal_alignment_separates_concepts() {
    // The system-level property every backend must deliver: frames showing
    // a concept embed near text queries naming that concept, with a margin
    // the retrieval oracle can rely on.
    let be = backend();
    let m = be.model().clone();
    let codes = be.concept_codes().unwrap();
    let tok = Tokenizer::from_model(be.model());
    let target = 7usize;

    let mut frames = Vec::new();
    for i in 0..8u64 {
        let mut f = noisy_frame(130 + i, m.img_size);
        let c = if i < 4 { target } else { (target + 1 + i as usize) % codes.len() };
        f.blend_block(0, 0, m.patch, &codes[c], 0.8);
        frames.push(f);
    }
    let mut flat = Vec::new();
    for f in &frames {
        flat.extend_from_slice(f.data());
    }
    let embs = be.embed_image(&flat, 8).unwrap();
    let qvec = be
        .embed_text(&tok.tokenize(&format!("what happened with concept{target:02}")))
        .unwrap();

    let sims: Vec<f32> = embs.iter().map(|e| dot(&qvec, e)).collect();
    let min_match = sims[..4].iter().cloned().fold(f32::INFINITY, f32::min);
    let max_other = sims[4..].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert!(
        min_match > max_other,
        "backend must separate match vs non-match: {sims:?}"
    );
    assert!(
        min_match - max_other > 0.2,
        "margin too small for the retrieval oracle: {sims:?}"
    );
}

#[test]
fn concept_side_files_consistent() {
    let be = backend();
    let m = be.model().clone();
    let codes = be.concept_codes().unwrap();
    let dirs = be.concept_dirs().unwrap();
    assert_eq!(codes.len(), m.n_concepts);
    assert_eq!(dirs.len(), m.n_concepts);
    assert_eq!(codes[0].len(), m.patch * m.patch * 3);
    assert_eq!(dirs[0].len(), m.d_embed);
    // codes are pixel values
    for row in &codes {
        assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }
}
