//! Cross-language integration tests: the Rust PJRT execution path must
//! reproduce the Python reference numerics recorded in the golden files at
//! `make artifacts` time.  This is the authoritative proof that the HLO
//! text round-trip (jax → text → xla crate parser → PJRT CPU) is lossless.

use venus::runtime::Runtime;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn read_f32(rt: &Runtime, key: &str) -> Vec<f32> {
    rt.manifest().read_f32_file(key).unwrap().0
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn golden_image_embedding_matches_python() {
    let rt = runtime();
    let img = read_f32(&rt, "golden_image");
    let want = read_f32(&rt, "golden_image_emb");
    let got = rt.embed_image(&img, 1).unwrap();
    let d = max_abs_diff(&got[0], &want);
    assert!(d < 5e-4, "image embedding diverged: max|Δ| = {d}");
}

#[test]
fn golden_text_embedding_matches_python() {
    let rt = runtime();
    let tokens = rt.manifest().read_i32_file("golden_tokens").unwrap().0;
    let want = read_f32(&rt, "golden_text_emb");
    let got = rt.embed_text(&tokens).unwrap();
    let d = max_abs_diff(&got, &want);
    assert!(d < 5e-4, "text embedding diverged: max|Δ| = {d}");
}

#[test]
fn golden_scene_features_match_python() {
    let rt = runtime();
    let img = read_f32(&rt, "golden_image");
    let want = read_f32(&rt, "golden_scene_feat");
    // scene_feat artifact is batch-8: tile the golden image
    let mut batch = Vec::with_capacity(img.len() * 8);
    for _ in 0..8 {
        batch.extend_from_slice(&img);
    }
    let got = rt.scene_features(&batch, 8).unwrap();
    for row in &got {
        let d = max_abs_diff(row, &want);
        assert!(d < 1e-4, "scene features diverged: max|Δ| = {d}");
    }
}

#[test]
fn embeddings_are_unit_norm() {
    let rt = runtime();
    let img = read_f32(&rt, "golden_image");
    let emb = rt.embed_image(&img, 1).unwrap();
    let norm: f32 = emb[0].iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4, "norm = {norm}");
}

#[test]
fn batched_image_tower_consistent_across_batch_sizes() {
    let rt = runtime();
    let img = read_f32(&rt, "golden_image");
    let e1 = rt.embed_image(&img, 1).unwrap()[0].clone();
    let mut b8 = Vec::new();
    for _ in 0..8 {
        b8.extend_from_slice(&img);
    }
    let e8 = rt.embed_image(&b8, 8).unwrap();
    for row in &e8 {
        let d = max_abs_diff(row, &e1);
        assert!(d < 1e-4, "batch-8 row diverged from batch-1: {d}");
    }
}

#[test]
fn similarity_kernel_matches_native_softmax() {
    let rt = runtime();
    let m = rt.model();
    // deterministic unit-norm index rows
    let mut rng = venus::util::rng::Pcg64::seeded(99);
    let n_valid = 700;
    let mut index = vec![0.0f32; m.sim_rows * m.d_embed];
    for r in 0..n_valid {
        let row = &mut index[r * m.d_embed..(r + 1) * m.d_embed];
        for x in row.iter_mut() {
            *x = rng.normal();
        }
        venus::util::l2_normalize(row);
    }
    let query: Vec<f32> = index[3 * m.d_embed..4 * m.d_embed].to_vec();
    let tau = 0.1;
    let (scores, probs) = rt.similarity(&query, &index, n_valid, tau).unwrap();
    assert_eq!(scores.len(), n_valid);
    // native recompute
    let mut want_scores = vec![0.0f32; n_valid];
    for r in 0..n_valid {
        want_scores[r] =
            venus::util::dot(&query, &index[r * m.d_embed..(r + 1) * m.d_embed]);
    }
    let mut want_probs = vec![0.0f32; n_valid];
    venus::util::softmax_temp(&want_scores, tau, &mut want_probs);
    assert!(max_abs_diff(&scores, &want_scores) < 1e-4);
    assert!(max_abs_diff(&probs, &want_probs) < 1e-4);
    // exact-match row must dominate
    let argmax = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax, 3);
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "probs sum {sum}");
}

#[test]
fn fused_entry_accepts_aux_tokens() {
    let rt = runtime();
    let m = rt.model();
    let img = read_f32(&rt, "golden_image");
    let mut batch = Vec::new();
    for _ in 0..8 {
        batch.extend_from_slice(&img);
    }
    // concept 5 is planted in the golden image; aux prompt mentions it
    let concept_token = (m.concept_token_base + 5) as i32;
    let mut aux = vec![0i32; 8 * m.seq_len];
    for b in 0..8 {
        aux[b * m.seq_len] = concept_token;
    }
    let fused = rt.embed_fused(&batch, &aux, 8).unwrap();
    let plain = rt.embed_image(&batch, 8).unwrap();
    // aux prompt must sharpen the planted concept's direction
    let dirs = rt.concept_dirs().unwrap();
    let mut u = dirs[5].clone();
    venus::util::l2_normalize(&mut u);
    let f = venus::util::dot(&fused[0], &u);
    let p = venus::util::dot(&plain[0], &u);
    assert!(
        f > p,
        "aux prompt should raise concept-5 alignment: fused {f} vs plain {p}"
    );
}

#[test]
fn concept_side_files_consistent() {
    let rt = runtime();
    let m = rt.model();
    let codes = rt.concept_codes().unwrap();
    let dirs = rt.concept_dirs().unwrap();
    assert_eq!(codes.len(), m.n_concepts);
    assert_eq!(dirs.len(), m.n_concepts);
    assert_eq!(codes[0].len(), m.patch * m.patch * 3);
    assert_eq!(dirs[0].len(), m.d_embed);
    // codes are pixel values
    for row in &codes {
        assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }
}
