//! Determinism gate for the parallel scoring pool (tier-1; DESIGN.md
//! §Parallel-Query): pooled scatter-gather scoring — and the selections
//! built on top of it — is **bit-identical** to the serial path at every
//! worker count, across stream scopes × retrieval modes × tier mixes
//! (hot-only / cold-heavy / recovered-from-disk) × segment formats
//! (v1 plain-f32 / SQ8 + coarse probing).
//!
//! The pool parallelizes across rows and segments only: each task writes
//! a pre-carved disjoint slice of the merged buffer and the per-row FP
//! op order inside `dot_batch_into` is the serial kernel's, so equality
//! here is exact bit equality, not tolerance.

use std::path::PathBuf;
use std::sync::Arc;

use venus::backend::{self, EmbedBackend};
use venus::config::{MemoryConfig, RetrievalConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::memory::{ClusterRecord, Hierarchy, MemoryFabric, StreamId, StreamScope};
use venus::util::rng::Pcg64;
use venus::util::scorer::ScorePool;

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "venus-scoredet-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const CLUSTERS: usize = 8;

/// Unit-norm cluster centers, deterministic for a given rng.
fn centers(rng: &mut Pcg64, d: usize) -> Vec<Vec<f32>> {
    (0..CLUSTERS)
        .map(|_| {
            let mut c: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut c);
            c
        })
        .collect()
}

/// Fill a shard with cluster-coherent runs (temporal locality), sealing
/// segments as the hot budget overflows.
fn fill(h: &mut Hierarchy, d: usize, n: usize, run: usize, seed: u64) {
    let stream = h.stream();
    let mut rng = Pcg64::seeded(seed);
    let cs = centers(&mut rng, d);
    for i in 0..n {
        let c = &cs[(i / run) % CLUSTERS];
        let mut v: Vec<f32> = c.iter().map(|x| x + 0.15 * rng.normal()).collect();
        venus::util::l2_normalize(&mut v);
        h.archive_frame(i as u64, &venus::video::frame::Frame::filled(8, [0.5; 3]))
            .unwrap();
        h.insert(
            &v,
            ClusterRecord {
                stream,
                scene_id: i,
                centroid_frame: i as u64,
                members: vec![i as u64],
            },
        )
        .unwrap();
    }
}

/// Cold-heavy config: 256-record segments, hot budget ≈ 2 segments.
fn cold_heavy(d: usize, quantized: bool, nprobe: usize, centroids: usize) -> MemoryConfig {
    let rec_bytes = d * 4 + std::mem::size_of::<ClusterRecord>() + 8;
    MemoryConfig {
        segment_records: 256,
        hot_budget_bytes: 2 * 256 * rec_bytes,
        cold_cache_segments: 64,
        quantization: if quantized { "sq8".into() } else { "none".into() },
        coarse_nprobe: nprobe,
        coarse_centroids_per_segment: centroids,
        ..Default::default()
    }
}

/// Hot-only config: budget so large nothing ever demotes.
fn hot_only(_d: usize) -> MemoryConfig {
    MemoryConfig {
        hot_budget_bytes: usize::MAX / 2,
        ..Default::default()
    }
}

fn unit_queries(d: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut q);
            q
        })
        .collect()
}

/// Assert pooled scoring is bit-identical to serial scoring on one shard
/// at every worker count.
fn assert_shard_bit_identical(h: &Hierarchy, d: usize, tag: &str) {
    let queries = unit_queries(d, 6, 0xdead ^ h.len() as u64);
    let mut serial = Vec::new();
    let mut pooled = Vec::new();
    for workers in WORKER_COUNTS {
        let pool = ScorePool::new(workers);
        for (qi, q) in queries.iter().enumerate() {
            h.score_all(q, &mut serial).unwrap();
            h.score_all_pooled(&pool, q, &mut pooled).unwrap();
            assert_eq!(serial.len(), pooled.len(), "{tag}: row count (q{qi})");
            for (i, (s, p)) in serial.iter().zip(&pooled).enumerate() {
                assert_eq!(
                    s.to_bits(),
                    p.to_bits(),
                    "{tag}: score {i} drifts under {workers} workers (q{qi}): {s} vs {p}"
                );
            }
        }
        assert!(
            pool.gauges().tasks_total > 0,
            "{tag}: pooled path never reached the pool at {workers} workers"
        );
    }
}

/// Shard-level bit-identity across tier mixes and segment formats.
#[test]
fn pooled_shard_scores_are_bit_identical_to_serial() {
    let d = 32;
    let tmp = TempDir::new("shard");
    let n = 1024;
    let run = 256;

    // hot-only: the pool degenerates to one hot-index task
    let mut hot =
        Hierarchy::durable(&hot_only(d), d, StreamId(0), &tmp.0.join("hot"), 8).unwrap();
    fill(&mut hot, d, 512, run, 3);
    assert_eq!(hot.tier_stats().cold_records, 0, "shard must stay hot-only");
    assert_shard_bit_identical(&hot, d, "hot-only");

    // cold-heavy, v1 plain-f32 segments, no pruning
    let v1_dir = tmp.0.join("v1");
    let mut v1 =
        Hierarchy::durable(&cold_heavy(d, false, 0, 0), d, StreamId(0), &v1_dir, 8).unwrap();
    fill(&mut v1, d, n, run, 42);
    assert!(v1.tier_stats().cold_records > n / 2, "tier split not cold-heavy");
    assert_shard_bit_identical(&v1, d, "cold-v1");

    // cold-heavy, SQ8-quantized segments with coarse probing (pruned
    // segments are NEG_INFINITY-filled on both paths)
    let mut sq8 =
        Hierarchy::durable(&cold_heavy(d, true, 4, 8), d, StreamId(0), &tmp.0.join("sq8"), 8)
            .unwrap();
    fill(&mut sq8, d, n, run, 42);
    assert!(sq8.tier_stats().cold_quantized, "shard must scan SQ8");
    assert_shard_bit_identical(&sq8, d, "cold-sq8");

    // recovered: flush + reopen the v1 shard from disk (cold tier comes
    // back from sealed segments, hot tier from the WAL tail)
    v1.flush().unwrap();
    drop(v1);
    let recovered =
        Hierarchy::durable(&cold_heavy(d, false, 0, 0), d, StreamId(0), &v1_dir, 8).unwrap();
    assert_eq!(recovered.len(), n, "recovery must restore every record");
    assert_shard_bit_identical(&recovered, d, "recovered");
}

/// Build a 2-stream durable fabric, fill both shards, flush, and reopen
/// it so the engine test also runs over recovered segments.
fn reopened_fabric(cfg: &MemoryConfig, d: usize, dir: &std::path::Path) -> Arc<MemoryFabric> {
    let fabric = MemoryFabric::open(cfg, d, 2, 8, dir).unwrap();
    for (i, shard) in fabric.shards().iter().enumerate() {
        let mut g = shard.write();
        fill(&mut g, d, 768, 256, 0x51ed + i as u64);
    }
    fabric.flush().unwrap();
    drop(fabric);
    Arc::new(MemoryFabric::open(cfg, d, 2, 8, dir).unwrap())
}

/// Engine-level gate: with a pool attached, `retrieve_scoped_with`
/// selections (frames, scores, draw counts) are bit-identical to the
/// serial engine at every worker count, across scopes × modes, over a
/// recovered 2-shard fabric — in both plain-f32 and SQ8 fabrics.
#[test]
fn pooled_selections_match_serial_across_scopes_and_modes() {
    let be = backend::shared_default().unwrap();
    let d = be.model().d_embed;
    let retrieval = RetrievalConfig::default();
    let budget = retrieval.budget;

    let scopes = [StreamScope::All, StreamScope::One(StreamId(0)), StreamScope::One(StreamId(1))];
    let modes = [
        RetrievalMode::Akr,
        RetrievalMode::FixedSampling(budget),
        RetrievalMode::TopK(budget),
    ];
    let texts = ["what happened with concept01", "person near the red car"];

    for quantized in [false, true] {
        let tmp = TempDir::new(if quantized { "engine-sq8" } else { "engine-v1" });
        let cfg = cold_heavy(d, quantized, if quantized { 4 } else { 0 }, if quantized { 8 } else { 0 });
        let fabric = reopened_fabric(&cfg, d, &tmp.0);

        for workers in WORKER_COUNTS {
            let pool = Arc::new(ScorePool::new(workers));
            // fresh engines per worker count: identical seeds ⇒ identical
            // rng streams ⇒ any divergence below is a scoring difference
            let mut serial = QueryEngine::new(
                EmbedEngine::default_backend(false).unwrap(),
                Arc::clone(&fabric),
                retrieval.clone(),
                7,
            );
            let mut pooled = QueryEngine::new(
                EmbedEngine::default_backend(false).unwrap(),
                Arc::clone(&fabric),
                retrieval.clone(),
                7,
            )
            .with_pool(Arc::clone(&pool));

            for scope in scopes {
                for mode in modes {
                    for text in texts {
                        let a = serial.retrieve_scoped_with(text, scope, mode).unwrap();
                        let b = pooled.retrieve_scoped_with(text, scope, mode).unwrap();
                        assert_eq!(
                            a.selection.frames, b.selection.frames,
                            "selection drifts: sq8={quantized} {workers}w {scope:?} {mode:?}"
                        );
                        assert_eq!(
                            a.draws, b.draws,
                            "draw count drifts: sq8={quantized} {workers}w {scope:?} {mode:?}"
                        );
                        assert_eq!(a.frame_scores.len(), b.frame_scores.len());
                        for (x, y) in a.frame_scores.iter().zip(&b.frame_scores) {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "frame score drifts: sq8={quantized} {workers}w {scope:?} {mode:?}"
                            );
                        }
                    }
                }
            }
            assert!(
                pool.gauges().tasks_total > 0,
                "pooled engine never reached the pool at {workers} workers"
            );
        }
    }
}
