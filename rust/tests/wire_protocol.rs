//! Wire-protocol integration suite: a real TCP gateway over an
//! ephemeral port, concurrent clients, equivalence with the in-process
//! serving path, lane priority + deadline shedding observed from the
//! client side, connection-budget admission, and a malformed-frame
//! robustness suite (every bad input fails one connection, never the
//! process).

use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use venus::api::{ApiError, CacheStatus, Priority, QueryRequest, QueryResponse};
use venus::config::{MemoryConfig, VenusConfig};
use venus::coordinator::query::RetrievalMode;
use venus::memory::{
    ClusterRecord, Hierarchy, InMemoryRaw, MemoryFabric, RawStore, StreamId, StreamScope,
};
use venus::net::wire::{read_frame, Gateway, ServerMsg, WireClient, WireError};
use venus::server::Service;
use venus::util::rng::Pcg64;
use venus::util::sync::OrderedRwLock;
use venus::video::frame::Frame;

const MAX: usize = 1 << 20;

/// A deterministic fabric: `streams` shards, each with `clusters`
/// random-unit-vector records over 4-frame clusters (same construction
/// as the api_protocol suite).
fn seeded_fabric(d: usize, streams: usize, clusters: u64, seed: u64) -> Arc<MemoryFabric> {
    let raws: Vec<Box<dyn RawStore>> =
        (0..streams).map(|_| Box::new(InMemoryRaw::new(8)) as Box<dyn RawStore>).collect();
    let fabric = Arc::new(MemoryFabric::new(&MemoryConfig::default(), d, raws).unwrap());
    let mut rng = Pcg64::seeded(seed);
    for sid in 0..streams as u16 {
        let shard: &Arc<OrderedRwLock<Hierarchy>> = fabric.shard(StreamId(sid)).unwrap();
        let mut g = shard.write();
        for c in 0..clusters {
            for f in c * 4..(c + 1) * 4 {
                g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
            }
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            g.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(sid),
                    scene_id: c as usize,
                    centroid_frame: c * 4,
                    members: (c * 4..(c + 1) * 4).collect(),
                },
            )
            .unwrap();
        }
    }
    fabric
}

fn embed_dim() -> usize {
    venus::embed::EmbedEngine::default_backend(false).unwrap().d_embed()
}

/// Service + gateway over an ephemeral port.
fn start_gateway(
    cfg: &VenusConfig,
    fabric: Arc<MemoryFabric>,
    seed: u64,
) -> (Arc<Service>, Gateway) {
    let service = Arc::new(Service::start(cfg, fabric, seed).unwrap());
    let gateway = Gateway::start(&cfg.wire, Arc::clone(&service)).unwrap();
    (service, gateway)
}

fn wire_cfg(cfg: &mut VenusConfig) {
    cfg.wire.listen = "127.0.0.1:0".into();
}

/// Tear down in the durability-safe order and return the final service.
fn teardown(gateway: Gateway, service: Arc<Service>) -> Service {
    gateway.shutdown();
    Arc::try_unwrap(service).ok().expect("gateway released its service handle")
}

fn raw_conn(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn send_raw(stream: &mut TcpStream, bytes: &[u8]) {
    use std::io::Write;
    let _ = stream.write_all(bytes);
    let _ = stream.flush();
}

fn frame_bytes(payload: &[u8]) -> Vec<u8> {
    let mut out = (payload.len() as u32).to_be_bytes().to_vec();
    out.extend_from_slice(payload);
    out
}

/// A full health probe: fresh connection, handshake, one query.
fn assert_healthy(addr: SocketAddr) {
    let mut client = WireClient::connect(addr).expect("gateway accepts a healthy client");
    let response = client
        .query(QueryRequest::new("health probe").mode(RetrievalMode::TopK(2)))
        .expect("transport healthy")
        .expect("query served");
    assert!(response.evidence.len() <= 2);
}

/// Acceptance: ≥8 concurrent clients over a real socket get responses
/// byte-identical (evidence ids/timestamps/scores, cache status, draw
/// count) to the in-process `Service::call` → `retrieve_request` path.
#[test]
fn eight_concurrent_clients_match_the_in_process_path() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 2, 10, 0x11fe);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    // cache off: both paths run the full deterministic (TopK) edge path
    cfg.api.cache_entries = 0;
    let (service, gateway) = start_gateway(&cfg, fabric, 7);
    let addr = gateway.local_addr();

    let requests: Vec<QueryRequest> = (0..8)
        .map(|i| {
            let scope = if i % 3 == 0 {
                StreamScope::All
            } else {
                StreamScope::One(StreamId((i % 2) as u16))
            };
            QueryRequest::new(format!("what happened with concept0{} variant {i}", i % 4))
                .mode(RetrievalMode::TopK(4))
                .scope(scope)
        })
        .collect();

    // in-process ground truth through the very same service
    let expected: Vec<QueryResponse> =
        requests.iter().map(|r| service.call(r.clone()).unwrap()).collect();

    // all 8 connections are live before any query flies
    let barrier = Barrier::new(requests.len());
    let wire: Vec<QueryResponse> = std::thread::scope(|s| {
        let handles: Vec<_> = requests
            .iter()
            .map(|r| {
                let barrier = &barrier;
                s.spawn(move || {
                    let mut client = WireClient::connect(addr).unwrap();
                    barrier.wait();
                    client.query(r.clone()).unwrap().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for ((request, exp), got) in requests.iter().zip(&expected).zip(&wire) {
        let exp_evidence = exp.to_json().get("evidence").unwrap().to_string();
        let got_evidence = got.to_json().get("evidence").unwrap().to_string();
        assert_eq!(got_evidence, exp_evidence, "evidence bytes differ for {request:?}");
        assert_eq!(got.evidence, exp.evidence);
        assert_eq!(got.cache, exp.cache);
        assert_eq!(got.cache, CacheStatus::Bypass, "cache disabled on both paths");
        assert_eq!(got.draws, exp.draws);
    }

    let stats = gateway.stats();
    assert_eq!(stats.accepted_conns, 8);
    assert_eq!(stats.refused_conns, 0);
    assert_eq!(stats.protocol_errors, 0);

    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.completed(), 16, "8 in-process + 8 wire");
    assert_eq!(snap.queued(), 0);
    assert_eq!(snap.failed, 0);
}

/// Acceptance: interactive-vs-batch priority and deadline shedding are
/// observable from the client side of the socket.
#[test]
fn lane_priority_and_shedding_observable_from_clients() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 12, 0x9a7e);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    cfg.server.workers = 1; // one worker: queueing order is the schedule
    cfg.api.cache_entries = 0;
    // the pile below must QUEUE, not get rejected or refused: deepen the
    // batch lane and the connection budget past the largest calibrated
    // pile (+ blocker, interactive, and the doomed query)
    cfg.api.batch_depth = Some(256);
    cfg.wire.max_conns = 128;
    let (service, gateway) = start_gateway(&cfg, fabric, 23);
    let addr = gateway.local_addr();

    // calibrate one cold query (connect + handshake + full edge path)
    let t0 = Instant::now();
    let mut probe = WireClient::connect(addr).unwrap();
    probe.query(QueryRequest::new("calibration probe query")).unwrap().unwrap();
    let cold = t0.elapsed().max(Duration::from_millis(1));
    drop(probe);

    // enough batch work to keep the single worker busy for >= ~200 ms
    // even on machines much faster than the calibration run suggests
    let batch_n = ((0.2 / cold.as_secs_f64()).ceil() as usize).clamp(8, 96);

    let done: Mutex<Vec<(&'static str, Instant)>> = Mutex::new(Vec::new());
    let shed_result: Mutex<Option<Result<QueryResponse, ApiError>>> = Mutex::new(None);
    // declared before the scope: scoped threads borrow it for 'scope
    let barrier = Barrier::new(batch_n);
    std::thread::scope(|s| {
        // blocker occupies the worker while the pile builds up
        let done_ref = &done;
        s.spawn(move || {
            let mut c = WireClient::connect(addr).unwrap();
            c.query(QueryRequest::new("blocker query zero").priority(Priority::Batch))
                .unwrap()
                .unwrap();
            done_ref.lock().unwrap().push(("batch", Instant::now()));
        });
        std::thread::sleep(Duration::from_millis(10));

        for i in 0..batch_n {
            let barrier = &barrier;
            let done_ref = &done;
            s.spawn(move || {
                let mut c = WireClient::connect(addr).unwrap();
                barrier.wait();
                c.query(
                    QueryRequest::new(format!("batch analytics question number {i}"))
                        .priority(Priority::Batch),
                )
                .unwrap()
                .unwrap();
                done_ref.lock().unwrap().push(("batch", Instant::now()));
            });
        }
        std::thread::sleep(Duration::from_millis(10));

        // the human arrives last — and must not wait out the batch pile
        let done_ref = &done;
        s.spawn(move || {
            let mut c = WireClient::connect(addr).unwrap();
            c.query(
                QueryRequest::new("urgent interactive question").priority(Priority::Interactive),
            )
            .unwrap()
            .unwrap();
            done_ref.lock().unwrap().push(("interactive", Instant::now()));
        });

        // a doomed batch query behind >= 200 ms of queue with a 1 ms
        // deadline: shed at dequeue, reported as the typed error
        let shed_ref = &shed_result;
        s.spawn(move || {
            let mut c = WireClient::connect(addr).unwrap();
            let r = c
                .query(
                    QueryRequest::new("doomed low priority question")
                        .priority(Priority::Batch)
                        .deadline(Duration::from_millis(1)),
                )
                .unwrap();
            *shed_ref.lock().unwrap() = Some(r);
            assert_eq!(c.errors(), 1, "the shed turn is recorded in the session history");
        });
    });

    let done = done.into_inner().unwrap();
    let interactive_done = done
        .iter()
        .find(|(k, _)| *k == "interactive")
        .map(|(_, t)| *t)
        .expect("interactive query completed");
    let last_batch_done = done
        .iter()
        .filter(|(k, _)| *k == "batch")
        .map(|(_, t)| *t)
        .max()
        .expect("batch queries completed");
    assert!(
        interactive_done < last_batch_done,
        "interactive ({interactive_done:?}) must jump the batch queue ({last_batch_done:?})"
    );
    match shed_result.into_inner().unwrap() {
        Some(Err(ApiError::DeadlineExceeded)) => {}
        other => panic!("expected DeadlineExceeded over the wire, got {other:?}"),
    }

    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.deadline_shed(), 1);
    assert_eq!(snap.interactive.completed, 2, "calibration probe + urgent query");
    assert_eq!(snap.batch.completed, 1 + batch_n as u64, "blocker + pile");
}

/// Sessions, stats (with live queue-depth and memory gauges), scope and
/// budget passthrough, and remote graceful shutdown.
#[test]
fn sessions_stats_and_remote_shutdown_over_the_wire() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 2, 8, 0x57a7);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    let (service, gateway) = start_gateway(&cfg, fabric, 11);
    let addr = gateway.local_addr();

    let mut a = WireClient::connect(addr).unwrap();
    let mut b = WireClient::connect(addr).unwrap();
    assert_ne!(a.session_id(), b.session_id(), "each connection is its own session");
    assert_eq!(a.streams(), 2, "handshake advertises the fabric size");
    a.ping().unwrap();

    let req = QueryRequest::new("what happened with concept01");
    let cold = a.query(req.clone()).unwrap().unwrap();
    assert_eq!(cold.cache, CacheStatus::Miss);
    let warm = a.query(req).unwrap().unwrap();
    assert_eq!(warm.cache, CacheStatus::HitExact, "the semantic cache serves wire traffic");
    assert_eq!(warm.frame_indices(), cold.frame_indices());
    assert_eq!(a.history().len(), 2);
    assert_eq!(a.cache_hits(), 1);
    assert_eq!(a.errors(), 0);

    // scope + budget overrides reach the engine across the wire
    let scoped = b
        .query(
            QueryRequest::new("what is on camera one")
                .scope(StreamScope::One(StreamId(1)))
                .mode(RetrievalMode::FixedSampling(6))
                .budget(4),
        )
        .unwrap()
        .unwrap();
    assert_eq!(scoped.draws, 4, "budget override applied");
    assert!(scoped.streams().iter().all(|&s| s == StreamId(1)), "scope respected");

    let snap = b.stats().unwrap();
    assert!(snap.completed() >= 3);
    assert_eq!(snap.queued(), 0, "idle lanes report zero live occupancy");
    assert!(snap.memory.is_some(), "fabric gauges ride the stats reply");
    assert!(snap.total_p50_s.is_some());

    assert!(!gateway.shutdown_requested());
    b.shutdown_server().unwrap();
    gateway.wait_for_shutdown_request();
    assert!(gateway.shutdown_requested());

    drop(a);
    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.queued(), 0);
}

/// The connection budget bounds concurrent clients: the (N+1)-th gets a
/// typed busy error, and the slot is reusable after a disconnect.
#[test]
fn connection_budget_refuses_politely_and_recovers() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 4, 0xb0d9);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    cfg.wire.max_conns = 2;
    let (service, gateway) = start_gateway(&cfg, fabric, 3);
    let addr = gateway.local_addr();

    let c1 = WireClient::connect(addr).unwrap();
    let mut c2 = WireClient::connect(addr).unwrap();
    let refused = WireClient::connect(addr);
    let msg = format!("{:#}", refused.err().expect("third client refused"));
    assert!(msg.contains("connection budget"), "typed busy error, got: {msg}");

    // freeing a slot makes room (the handler reaps the close first)
    drop(c1);
    let mut c3 = None;
    for _ in 0..250 {
        match WireClient::connect(addr) {
            Ok(c) => {
                c3 = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let mut c3 = c3.expect("slot freed after a client disconnected");
    c3.ping().unwrap();
    c2.ping().unwrap();
    assert!(gateway.stats().refused_conns >= 1);

    drop(c2);
    drop(c3);
    teardown(gateway, service).shutdown();
}

/// Robustness acceptance: truncated / oversized / garbage frames and
/// malformed `QueryRequest` JSON each fail their one connection with a
/// typed error (or a plain close) — the gateway keeps serving healthy
/// clients after every single vector, and never panics or wedges.
#[test]
fn malformed_frames_fail_one_connection_never_the_gateway() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 4, 0xbad5eed);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    cfg.wire.max_frame_bytes = MAX;
    let (service, gateway) = start_gateway(&cfg, fabric, 5);
    let addr = gateway.local_addr();

    let truncated = {
        let mut v = 100u32.to_be_bytes().to_vec();
        v.extend_from_slice(b"short");
        v
    };
    let vectors: Vec<(&str, Vec<u8>)> = vec![
        ("http request", b"GET / HTTP/1.1\r\nHost: venus\r\n\r\n".to_vec()),
        ("4 GiB length prefix", 0xffff_ffffu32.to_be_bytes().to_vec()),
        ("zero length prefix", 0u32.to_be_bytes().to_vec()),
        ("garbage payload", frame_bytes(b"not json at all")),
        ("tag-less object", frame_bytes(br#"{"no":"type"}"#)),
        ("unknown message type", frame_bytes(br#"{"type":"teleport"}"#)),
        ("future protocol version", frame_bytes(br#"{"type":"hello","version":99}"#)),
        (
            "query before hello",
            frame_bytes(br#"{"type":"query","request":{"text":"hi","scope":"all"}}"#),
        ),
        ("truncated frame", truncated),
    ];
    for (name, bytes) in &vectors {
        let mut s = raw_conn(addr);
        send_raw(&mut s, bytes);
        let _ = s.shutdown(std::net::Shutdown::Write);
        // the server must answer with a typed protocol error or close
        // the connection — never hang, never take the process down
        if let Ok(v) = read_frame(&mut s, MAX) {
            let msg = ServerMsg::from_json(&v).unwrap();
            assert!(
                matches!(msg, ServerMsg::Error { error: WireError::Protocol(_) }),
                "vector '{name}': expected a protocol error, got {msg:?}"
            );
        }
        assert_healthy(addr);
    }

    // malformed QueryRequest JSON *after* a valid handshake: the typed
    // error arrives on a live, handshaken connection
    let mut s = raw_conn(addr);
    send_raw(&mut s, &frame_bytes(br#"{"type":"hello","version":1}"#));
    let ack = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    assert!(matches!(ack, ServerMsg::HelloAck { .. }));
    send_raw(&mut s, &frame_bytes(br#"{"type":"query","request":{"scope":"all"}}"#));
    let reply = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    assert!(
        matches!(reply, ServerMsg::Error { error: WireError::Protocol(_) }),
        "malformed QueryRequest must be a typed error, got {reply:?}"
    );
    drop(s);
    assert_healthy(addr);

    // property-style fuzz: random byte blobs, one connection each —
    // every one dies alone
    let mut rng = Pcg64::seeded(0xf077);
    for round in 0..16 {
        let n = rng.range(1, 64);
        let blob: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let mut s = raw_conn(addr);
        send_raw(&mut s, &blob);
        let _ = s.shutdown(std::net::Shutdown::Write);
        let _ = read_frame(&mut s, MAX); // whatever it says, it must say it promptly
        drop(s);
        if round % 4 == 3 {
            assert_healthy(addr);
        }
    }
    assert_healthy(addr);

    let stats = gateway.stats();
    assert!(
        stats.protocol_errors >= 6,
        "typed protocol errors were counted: {}",
        stats.protocol_errors
    );

    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain(), "bad frames never leak lane work");
    service.shutdown();
}

/// Per-tag robustness: every `"type"` tag the protocol defines (both
/// directions) has a malformed-frame vector here — a frame that carries
/// the tag but violates the envelope contract.  Client-side tags arrive
/// broken or out of order; server-side tags arrive on the wrong
/// direction entirely.  Each vector fails its one connection with a
/// typed error or a close, and the gateway keeps serving afterwards.
///
/// vlint's R4 rule cross-checks this list against `net/wire/proto.rs`:
/// a new envelope tag without a vector below is a lint error.
#[test]
fn every_envelope_tag_has_a_malformed_frame_vector() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 4, 0x7a95);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    cfg.wire.max_frame_bytes = MAX;
    let (service, gateway) = start_gateway(&cfg, fabric, 29);
    let addr = gateway.local_addr();

    let vectors: Vec<(&str, &[u8])> = vec![
        // client-direction tags, each violating its own contract
        ("hello without a version", br#"{"type":"hello"}"#),
        ("query before the handshake", br#"{"type":"query","request":{"text":"hi","scope":"all"}}"#),
        ("stats before the handshake", br#"{"type":"stats"}"#),
        ("ping before the handshake", br#"{"type":"ping"}"#),
        ("shutdown before the handshake", br#"{"type":"shutdown"}"#),
        ("trace with an unparseable id", br#"{"type":"trace","id":"not-hex"}"#),
        ("metrics_text before the handshake", br#"{"type":"metrics_text"}"#),
        // server-direction tags sent *to* the server: wrong direction
        ("hello_ack from a client", br#"{"type":"hello_ack","session":1,"streams":1,"version":1}"#),
        ("response from a client", br#"{"type":"response","response":{}}"#),
        ("error from a client", br#"{"type":"error","error":{"scope":"protocol","detail":"x"}}"#),
        ("pong from a client", br#"{"type":"pong"}"#),
        ("shutdown_ack from a client", br#"{"type":"shutdown_ack"}"#),
        // ingest-plane tags: pre-handshake they die like everything else
        // (the deeper violations — stale lease, out-of-order seq against
        // a live watermark, oversized batch — need an ingest hub and are
        // exercised end to end in tests/ingest_wire.rs)
        (
            "ingest_open before the handshake",
            br#"{"type":"ingest_open","stream":0,"frame_size":64,"fps":8.0}"#,
        ),
        (
            "ingest_frames before the handshake, out-of-order seq",
            br#"{"type":"ingest_frames","stream":0,"frames":[{"seq":5,"captured_unix_ms":0,"data":""}]}"#,
        ),
        // server-direction ingest tags sent *to* the server
        (
            "ingest_open_ack from a client",
            br#"{"type":"ingest_open_ack","stream":0,"next_seq":0}"#,
        ),
        (
            "ingest_ack from a client",
            br#"{"type":"ingest_ack","stream":0,"high_watermark":0,"backpressure":{"kind":"none"}}"#,
        ),
    ];
    for (name, payload) in &vectors {
        let mut s = raw_conn(addr);
        send_raw(&mut s, &frame_bytes(payload));
        let _ = s.shutdown(std::net::Shutdown::Write);
        if let Ok(v) = read_frame(&mut s, MAX) {
            let msg = ServerMsg::from_json(&v).unwrap();
            assert!(
                matches!(msg, ServerMsg::Error { error: WireError::Protocol(_) }),
                "vector '{name}': expected a typed protocol error, got {msg:?}"
            );
        }
        drop(s);
        assert_healthy(addr);
    }

    // after a valid handshake, ingest on a hub-less (query-only) gateway
    // is a typed protocol error — not a hang, not a crash
    let mut s = raw_conn(addr);
    send_raw(&mut s, &frame_bytes(br#"{"type":"hello","version":1}"#));
    let ack = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    assert!(matches!(ack, ServerMsg::HelloAck { .. }));
    send_raw(
        &mut s,
        &frame_bytes(br#"{"type":"ingest_open","stream":0,"frame_size":64,"fps":8.0}"#),
    );
    let reply = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    match reply {
        ServerMsg::Error { error: WireError::Protocol(msg) } => {
            assert!(msg.contains("ingest not enabled"), "{msg}")
        }
        other => panic!("expected a typed protocol error, got {other:?}"),
    }
    drop(s);
    assert_healthy(addr);

    // after a valid handshake, a trace fetch with a garbage id is a
    // typed protocol error on that connection
    let mut s = raw_conn(addr);
    send_raw(&mut s, &frame_bytes(br#"{"type":"hello","version":1}"#));
    let ack = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    assert!(matches!(ack, ServerMsg::HelloAck { .. }));
    send_raw(&mut s, &frame_bytes(br#"{"type":"trace","id":"zzz"}"#));
    let reply = ServerMsg::from_json(&read_frame(&mut s, MAX).unwrap()).unwrap();
    assert!(
        matches!(reply, ServerMsg::Error { error: WireError::Protocol(_) }),
        "unparseable trace id must be a typed error, got {reply:?}"
    );
    drop(s);
    assert_healthy(addr);

    let stats = gateway.stats();
    assert!(stats.protocol_errors >= vectors.len() as u64 - 1);
    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain());
    service.shutdown();
}

/// Regression for the poisoning cascade: a panic inside the query
/// handler must fail exactly that connection.  Before the gateway
/// switched to poison-recovering locks + `catch_unwind`, the first
/// handler panic poisoned the shared stats/conns mutexes and every
/// later `.lock().unwrap()` — in the accept loop included — cascaded,
/// wedging the whole gateway.
#[test]
fn handler_panic_fails_one_connection_never_the_gateway() {
    let d = embed_dim();
    let fabric = seeded_fabric(d, 1, 4, 0x9a71c);
    let mut cfg = VenusConfig::default();
    wire_cfg(&mut cfg);
    let (service, gateway) = start_gateway(&cfg, fabric, 31);
    let addr = gateway.local_addr();

    let mut victim = WireClient::connect(addr).unwrap();
    victim.ping().unwrap();
    gateway.inject_handler_panic();
    let lost = victim.query(QueryRequest::new("this query panics its handler"));
    assert!(lost.is_err(), "the panicking handler's connection dies, got {lost:?}");

    // the gateway is still alive: fresh connections handshake and serve,
    // and the shared stats lock is readable (i.e. not poisoned-and-fatal)
    assert_healthy(addr);
    let stats = gateway.stats();
    assert_eq!(stats.handler_panics, 1, "the panic is accounted, once");
    assert!(stats.accepted_conns >= 2);

    // ...and it still shuts down cleanly, with no leaked lane work (the
    // injected panic fires before the request reaches the service)
    let service = teardown(gateway, service);
    assert!(service.metrics.conserved_after_drain());
    let snap = service.shutdown();
    assert_eq!(snap.failed, 0);
}
