//! Offline stub of the `xla` PJRT bindings (API-compatible subset).
//!
//! The Venus PJRT backend (`venus::runtime`, behind the `pjrt` cargo
//! feature) compiles against this crate so the whole feature surface
//! type-checks without the XLA C++ runtime installed.  Semantics:
//!
//!   * [`Literal`] is fully functional: shape/dtype-checked host buffers
//!     with byte-exact round-trips (`create_from_shape_and_untyped_data`,
//!     `to_vec`, `element_count`) — the unit tests that exercise literal
//!     plumbing pass against the stub.
//!   * [`PjRtClient::cpu`], compilation, and execution return
//!     [`Error::Unavailable`]: there is no device runtime here.  Callers
//!     that probe for artifacts at startup (`Runtime::load_default`) fail
//!     cleanly and fall back to the native backend.
//!
//! To execute real AOT artifacts, replace this path dependency with the
//! actual `xla` bindings (`make artifacts` + Cargo `[patch]`; see the repo
//! Makefile and DESIGN.md §Backends).

use std::fmt;
use std::path::Path;

/// Stub error type: every device-side operation reports `Unavailable`.
#[derive(Debug)]
pub enum Error {
    /// The stub has no XLA runtime behind it.
    Unavailable(&'static str),
    /// Host-side shape/dtype validation failure (real behavior).
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(op) => write!(
                f,
                "xla stub: '{op}' requires the real xla bindings (this build \
                 type-checks the PJRT backend only; see Makefile)"
            ),
            Error::Shape(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the Venus artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Marker trait tying Rust scalar types to [`ElementType`]s.
pub trait NativeType: Sized + Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// A host-side literal: shape + dtype + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let n: usize = dims.iter().product();
        if data.len() != n * ty.byte_width() {
            return Err(Error::Shape(format!(
                "literal data is {} bytes, shape {dims:?} needs {}",
                data.len(),
                n * ty.byte_width()
            )));
        }
        Ok(Self { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn shape_dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error::Shape(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// De-tuple a tuple literal.  The stub never produces tuples (they only
    /// come back from execution), so this always reports `Unavailable`.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// PJRT device client.  The stub cannot create one.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &data)
            .unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn device_ops_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
