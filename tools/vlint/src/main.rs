//! vlint — Venus's repo-specific invariant linter.
//!
//! Clippy checks Rust; vlint checks *Venus*: the cross-file invariants
//! this codebase promises and a generic linter cannot see.  It walks
//! `rust/src` with a hand-written Rust-token lexer (comments, strings,
//! raw strings, char-vs-lifetime — no syn, no proc-macro, no deps) and
//! enforces five rules:
//!
//!   R1  No `.unwrap()` / `.expect()` / `panic!` / `unreachable!` in
//!       non-test code under `net/`, `server/`, `memory/`, `api/` — the
//!       serving hot paths return typed errors.  (`unwrap_or*`,
//!       `assert!`, indexing, and `std::panic::panic_any` in test hooks
//!       are fine: the rule targets the panic-on-Err/None family.)
//!   R2  Lock discipline: every shared lock goes through
//!       `util::sync::{OrderedMutex, OrderedRwLock, OrderedCondvar}`
//!       (poison-recovering, rank-checked in debug builds).  Any bare
//!       `Mutex` / `RwLock` / `Condvar` identifier outside
//!       `util/sync.rs` is an error.
//!   R3  Config-key hygiene: every `[section] key` string read in
//!       `config/mod.rs` must be declared in `KNOWN_KEYS` (the
//!       unknown-key rejection path), every `KNOWN_KEYS` entry must be
//!       read, and every entry must be documented in DESIGN.md (as a
//!       backticked `` `section.key` ``).
//!   R4  Wire-protocol coverage: every `"type"` envelope tag built via
//!       `tagged("...")` in `net/wire/proto.rs` must have a
//!       malformed-frame vector in `rust/tests/wire_protocol.rs`
//!       containing the literal `"type":"<tag>"`.
//!   R5  No `println!` / `process::exit` outside `cli/` (examples and
//!       benches live outside `rust/src`): library code reports through
//!       return values, diagnostics go to stderr.
//!
//! Violations resolve against the checked-in `vlint.toml` waiver file;
//! each waiver names one (rule, file) pair and carries a one-line
//! justification.  Waivers that match nothing are *errors* (staleness),
//! and R1/R2 waivers in the hot-path directories are rejected outright:
//! the panic and lock contracts there are not waivable.
//!
//! Test code is exempt everywhere: items annotated `#[test]` /
//! `#[cfg(test)]` (but not `#[cfg(not(test))]`) are masked out before
//! the rules run.
//!
//! Usage: `vlint [--root DIR] [--waivers FILE] [--design FILE]
//!         [--proto-tests FILE]` — run from the repo root (`make lint`
//!         does).  Exit 0 clean, 1 on violations, 2 on usage/IO errors.

use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

// --------------------------------------------------------------------
// Lexer
// --------------------------------------------------------------------

/// One Rust token, as coarse as the rules need.  String literals keep
/// their (uncooked) contents; numbers and chars keep nothing.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Tok {
    Ident(String),
    Str(String),
    Char,
    Lifetime,
    Num,
    Punct(u8),
}

#[derive(Clone, Debug)]
struct Token {
    tok: Tok,
    line: u32,
}

impl Token {
    fn is_punct(&self, c: u8) -> bool {
        self.tok == Tok::Punct(c)
    }

    fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize Rust source.  Comments (line, nested block, doc) vanish;
/// string/char/lifetime/number forms are recognized so their contents
/// can never masquerade as identifiers.  Unterminated forms lex to the
/// end of input rather than erroring: a lint pass must never die on the
/// file it is judging.
fn lex(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_char(b[i]) {
                i += 1;
            }
            let ident = &src[start..i];
            // string-literal prefixes: r"", r#""#, b"", br#""#, rb…
            let raw = matches!(ident, "r" | "br" | "rb");
            let bytes_only = ident == "b";
            if raw && i < n && (b[i] == b'"' || b[i] == b'#') {
                let mut hashes = 0usize;
                let mut j = i;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    j += 1;
                    let body_start = j;
                    let term = format!("\"{}", "#".repeat(hashes));
                    let end = src[body_start..].find(&term).map(|p| body_start + p).unwrap_or(n);
                    let body = &src[body_start..end];
                    toks.push(Token { tok: Tok::Str(body.to_string()), line });
                    line += body.bytes().filter(|&x| x == b'\n').count() as u32;
                    i = (end + term.len()).min(n);
                    continue;
                }
                // `r` / `br` not actually starting a raw string: plain ident
            }
            if bytes_only && i < n && b[i] == b'"' {
                let (tok, nl, next) = lex_quoted(src, i, line);
                toks.push(tok);
                line = nl;
                i = next;
                continue;
            }
            toks.push(Token { tok: Tok::Ident(ident.to_string()), line });
        } else if c == b'"' {
            let (tok, nl, next) = lex_quoted(src, i, line);
            toks.push(tok);
            line = nl;
            i = next;
        } else if c == b'\'' {
            // lifetime ('a not followed by ') vs char literal ('a', '\n')
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_char(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == b'\'' {
                    toks.push(Token { tok: Tok::Char, line });
                    i = j + 1;
                } else {
                    toks.push(Token { tok: Tok::Lifetime, line });
                    i = j;
                }
            } else {
                let mut j = i + 1;
                if j < n && b[j] == b'\\' {
                    j += 2;
                } else {
                    j += 1;
                }
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                toks.push(Token { tok: Tok::Char, line });
                i = (j + 1).min(n);
            }
        } else if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_ident_char(b[j]) {
                j += 1;
            }
            // a fraction dot belongs to the number ONLY when a digit
            // follows — `pair.0.unwrap()` must stay three tokens so R1
            // still sees the `.unwrap`
            if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident_char(b[j]) {
                    j += 1;
                }
            }
            // exponent sign: `1e-3`
            if j < n
                && (b[j] == b'+' || b[j] == b'-')
                && matches!(b[j - 1], b'e' | b'E')
                && j + 1 < n
                && b[j + 1].is_ascii_digit()
            {
                j += 1;
                while j < n && is_ident_char(b[j]) {
                    j += 1;
                }
            }
            toks.push(Token { tok: Tok::Num, line });
            i = j;
        } else {
            toks.push(Token { tok: Tok::Punct(c), line });
            i += 1;
        }
    }
    toks
}

/// Lex a `"…"` (or `b"…"`) literal starting at the opening quote.
/// Returns (token, updated line, index past the closing quote).
fn lex_quoted(src: &str, i: usize, mut line: u32) -> (Token, u32, usize) {
    let b = src.as_bytes();
    let n = b.len();
    let start_line = line;
    let mut j = i + 1;
    let mut body = String::new();
    while j < n && b[j] != b'"' {
        if b[j] == b'\\' && j + 1 < n {
            body.push_str(&src[j..(j + 2).min(n)]);
            j += 2;
        } else {
            if b[j] == b'\n' {
                line += 1;
            }
            body.push(b[j] as char);
            j += 1;
        }
    }
    (Token { tok: Tok::Str(body), line: start_line }, line, (j + 1).min(n))
}

// --------------------------------------------------------------------
// Test-region masking
// --------------------------------------------------------------------

/// Scan an attribute starting at `toks[i] == '#'`, `toks[i+1] == '['`.
/// Returns (index of the closing `]`, whether the attribute marks test
/// code).  `#[cfg(not(test))]` is *production* code.
fn scan_attr(toks: &[Token], i: usize) -> (usize, bool) {
    let mut depth = 0usize;
    let mut has_test = false;
    let mut has_not = false;
    let mut j = i + 1;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct(b'[') => depth += 1,
            Tok::Punct(b']') => {
                depth -= 1;
                if depth == 0 {
                    return (j, has_test && !has_not);
                }
            }
            Tok::Ident(s) if s == "test" => has_test = true,
            Tok::Ident(s) if s == "not" => has_not = true,
            _ => {}
        }
        j += 1;
    }
    (toks.len().saturating_sub(1), false)
}

/// `mask[k] == true` ⇔ token `k` lives in a `#[test]` / `#[cfg(test)]`
/// item (the whole following item: attribute through the matching
/// closing brace, or the `;` for brace-less items).
fn test_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let starts_attr =
            toks[i].is_punct(b'#') && i + 1 < toks.len() && toks[i + 1].is_punct(b'[');
        if !starts_attr {
            i += 1;
            continue;
        }
        let (end, is_test) = scan_attr(toks, i);
        if !is_test {
            i = end + 1;
            continue;
        }
        // swallow any further attributes stacked on the same item
        let mut j = end + 1;
        while j + 1 < toks.len() && toks[j].is_punct(b'#') && toks[j + 1].is_punct(b'[') {
            let (e, _) = scan_attr(toks, j);
            j = e + 1;
        }
        // the item body: to the matching `}` or a top-level `;`.  A `}`
        // with no `{` open means the attribute sat on a field/variant
        // and the enclosing item just closed — stop before it.
        let mut brace = 0u32;
        let mut include_j = true;
        while j < toks.len() {
            match &toks[j].tok {
                Tok::Punct(b'{') => brace += 1,
                Tok::Punct(b'}') => {
                    if brace == 0 {
                        include_j = false;
                        break;
                    }
                    brace -= 1;
                    if brace == 0 {
                        break;
                    }
                }
                Tok::Punct(b';') if brace == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let stop = if include_j { (j + 1).min(toks.len()) } else { j };
        for m in &mut mask[i..stop] {
            *m = true;
        }
        i = stop;
    }
    mask
}

// --------------------------------------------------------------------
// Violations
// --------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Violation {
    rule: &'static str,
    /// Repo-relative path, forward slashes.
    path: String,
    line: u32,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Directories under `rust/src/` where R1's panic ban applies.
const R1_SCOPE: [&str; 4] = ["net/", "server/", "memory/", "api/"];

fn in_r1_scope(rel: &str) -> bool {
    R1_SCOPE.iter().any(|d| rel.starts_with(d))
}

// --------------------------------------------------------------------
// R1 + R2 + R5: the per-file token rules
// --------------------------------------------------------------------

/// Run the per-file rules over one `rust/src` file.  `rel` is the path
/// relative to `rust/src` (forward slashes).
fn check_tokens(rel: &str, toks: &[Token], mask: &[bool]) -> Vec<Violation> {
    let mut out = Vec::new();
    let path = format!("rust/src/{rel}");
    let hot = in_r1_scope(rel);
    let is_sync = rel == "util/sync.rs";
    let in_cli = rel.starts_with("cli/");
    for (i, t) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let Some(id) = t.ident() else { continue };
        let prev = |k: usize| i.checked_sub(k).map(|p| &toks[p]);
        let next = i + 1 < toks.len();
        if hot {
            if matches!(id, "unwrap" | "expect") && prev(1).is_some_and(|p| p.is_punct(b'.')) {
                out.push(Violation {
                    rule: "R1",
                    path: path.clone(),
                    line: t.line,
                    msg: format!(
                        ".{id}() in a serving hot path — return a typed error \
                         (or use the poison-recovering util::sync guards)"
                    ),
                });
            }
            if matches!(id, "panic" | "unreachable") && next && toks[i + 1].is_punct(b'!') {
                out.push(Violation {
                    rule: "R1",
                    path: path.clone(),
                    line: t.line,
                    msg: format!("{id}! in a serving hot path — return a typed error"),
                });
            }
        }
        if !is_sync && matches!(id, "Mutex" | "RwLock" | "Condvar") {
            out.push(Violation {
                rule: "R2",
                path: path.clone(),
                line: t.line,
                msg: format!(
                    "raw std::sync::{id} — use util::sync::Ordered{id} with a declared \
                     rank (see util::sync::ranks)"
                ),
            });
        }
        if !in_cli {
            if id == "println" && next && toks[i + 1].is_punct(b'!') {
                out.push(Violation {
                    rule: "R5",
                    path: path.clone(),
                    line: t.line,
                    msg: "println! outside cli/ — return values or eprintln! for diagnostics"
                        .to_string(),
                });
            }
            if id == "exit"
                && prev(1).is_some_and(|p| p.is_punct(b':'))
                && prev(2).is_some_and(|p| p.is_punct(b':'))
                && prev(3).and_then(|p| p.ident()) == Some("process")
            {
                out.push(Violation {
                    rule: "R5",
                    path: path.clone(),
                    line: t.line,
                    msg: "process::exit outside cli/ — bubble a Result to main".to_string(),
                });
            }
        }
    }
    out
}

// --------------------------------------------------------------------
// R3: config-key hygiene
// --------------------------------------------------------------------

const CONFIG_ACCESSORS: [&str; 5] = ["f64_or", "usize_or", "bool_or", "str_or", "get"];

/// Cross-check `config/mod.rs` against itself and DESIGN.md: reads vs
/// the `KNOWN_KEYS` declaration vs the documented key table.
fn check_config(toks: &[Token], mask: &[bool], design: &str) -> Vec<Violation> {
    let path = "rust/src/config/mod.rs";
    let mut known: Vec<(String, u32)> = Vec::new();
    let mut reads: Vec<(String, u32)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if mask[i] {
            i += 1;
            continue;
        }
        // the declaration: `const KNOWN_KEYS: … = &[ "…", … ];`
        if toks[i].ident() == Some("KNOWN_KEYS")
            && i + 1 < toks.len()
            && toks[i + 1].is_punct(b':')
        {
            let mut j = i + 1;
            while j < toks.len() && !toks[j].is_punct(b'=') {
                j += 1;
            }
            while j < toks.len() && !toks[j].is_punct(b';') {
                if let Tok::Str(s) = &toks[j].tok {
                    known.push((s.clone(), toks[j].line));
                }
                j += 1;
            }
            i = j + 1;
            continue;
        }
        // a read: `accessor("section.key", …)` with a literal first arg
        if let Some(id) = toks[i].ident() {
            if CONFIG_ACCESSORS.contains(&id)
                && i + 2 < toks.len()
                && toks[i + 1].is_punct(b'(')
            {
                if let Tok::Str(s) = &toks[i + 2].tok {
                    reads.push((s.clone(), toks[i + 2].line));
                }
            }
        }
        i += 1;
    }
    let known_set: BTreeSet<&str> = known.iter().map(|(k, _)| k.as_str()).collect();
    let read_set: BTreeSet<&str> = reads.iter().map(|(k, _)| k.as_str()).collect();
    let mut out = Vec::new();
    for (key, line) in &reads {
        if !known_set.contains(key.as_str()) {
            out.push(Violation {
                rule: "R3",
                path: path.to_string(),
                line: *line,
                msg: format!(
                    "config key '{key}' is read but not declared in KNOWN_KEYS \
                     (the unknown-key rejection would never accept it)"
                ),
            });
        }
    }
    for (key, line) in &known {
        if !design.contains(&format!("`{key}`")) {
            out.push(Violation {
                rule: "R3",
                path: path.to_string(),
                line: *line,
                msg: format!("config key '{key}' is not documented in DESIGN.md (`{key}`)"),
            });
        }
        if !read_set.contains(key.as_str()) {
            out.push(Violation {
                rule: "R3",
                path: path.to_string(),
                line: *line,
                msg: format!("KNOWN_KEYS entry '{key}' is never read — stale declaration"),
            });
        }
    }
    out
}

// --------------------------------------------------------------------
// R4: wire-protocol tag coverage
// --------------------------------------------------------------------

/// Every `tagged("…")` envelope tag in proto.rs needs a malformed-frame
/// vector (the literal `"type":"<tag>"`) in the wire integration suite.
fn check_proto(toks: &[Token], mask: &[bool], wire_tests: &str) -> Vec<Violation> {
    let mut tags: Vec<(String, u32)> = Vec::new();
    let mut seen = BTreeSet::new();
    for (i, t) in toks.iter().enumerate() {
        if mask[i] || t.ident() != Some("tagged") {
            continue;
        }
        if i + 2 < toks.len() && toks[i + 1].is_punct(b'(') {
            if let Tok::Str(s) = &toks[i + 2].tok {
                if seen.insert(s.clone()) {
                    tags.push((s.clone(), toks[i + 2].line));
                }
            }
        }
    }
    let mut out = Vec::new();
    for (tag, line) in tags {
        if !wire_tests.contains(&format!("\"type\":\"{tag}\"")) {
            out.push(Violation {
                rule: "R4",
                path: "rust/src/net/wire/proto.rs".to_string(),
                line,
                msg: format!(
                    "envelope tag '{tag}' has no malformed-frame vector in \
                     rust/tests/wire_protocol.rs (need a literal \"type\":\"{tag}\" case)"
                ),
            });
        }
    }
    out
}

// --------------------------------------------------------------------
// Waivers
// --------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Waiver {
    rule: String,
    path: String,
    reason: String,
    line: u32,
}

/// Parse the `vlint.toml` waiver file: `[[waiver]]` entries with
/// `rule = "…"`, `path = "…"`, `reason = "…"` string fields.  (A tiny
/// purpose-built parser — the format is fixed, not general TOML.)
fn parse_waivers(text: &str) -> Result<Vec<Waiver>, String> {
    let mut out: Vec<Waiver> = Vec::new();
    let mut cur: Option<(Waiver, u32)> = None;
    let finish = |cur: Option<(Waiver, u32)>, out: &mut Vec<Waiver>| -> Result<(), String> {
        if let Some((w, line)) = cur {
            if w.rule.is_empty() || w.path.is_empty() {
                return Err(format!("vlint.toml:{line}: waiver needs rule and path"));
            }
            if w.reason.trim().is_empty() {
                return Err(format!(
                    "vlint.toml:{line}: waiver for {} on {} has no justification \
                     (a one-line reason is required)",
                    w.rule, w.path
                ));
            }
            out.push(w);
        }
        Ok(())
    };
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx as u32 + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[waiver]]" {
            finish(cur.take(), &mut out)?;
            cur = Some((
                Waiver {
                    rule: String::new(),
                    path: String::new(),
                    reason: String::new(),
                    line: lineno,
                },
                lineno,
            ));
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("vlint.toml:{lineno}: expected `key = \"value\"`, got: {line}"));
        };
        let value = value.trim();
        if !(value.starts_with('"') && value.ends_with('"') && value.len() >= 2) {
            return Err(format!("vlint.toml:{lineno}: value must be a quoted string"));
        }
        let value = &value[1..value.len() - 1];
        let Some((w, _)) = cur.as_mut() else {
            return Err(format!("vlint.toml:{lineno}: field outside a [[waiver]] block"));
        };
        match key.trim() {
            "rule" => w.rule = value.to_string(),
            "path" => w.path = value.to_string(),
            "reason" => w.reason = value.to_string(),
            other => return Err(format!("vlint.toml:{lineno}: unknown field '{other}'")),
        }
    }
    finish(cur.take(), &mut out)?;
    Ok(out)
}

/// Resolve violations against the waiver list.  Returns the surviving
/// violations plus configuration errors (stale waivers, and R1/R2
/// waivers in the hot-path directories, which are never allowed).
fn apply_waivers(
    violations: Vec<Violation>,
    waivers: &[Waiver],
) -> (Vec<Violation>, Vec<String>) {
    let mut errors = Vec::new();
    for w in waivers {
        if matches!(w.rule.as_str(), "R1" | "R2") {
            let rel = w.path.strip_prefix("rust/src/").unwrap_or(&w.path);
            if in_r1_scope(rel) {
                errors.push(format!(
                    "vlint.toml:{}: {} waiver on {} rejected — the panic/lock contract \
                     in net/, server/, memory/, api/ is not waivable",
                    w.line, w.rule, w.path
                ));
            }
        }
    }
    let mut used = vec![false; waivers.len()];
    let surviving: Vec<Violation> = violations
        .into_iter()
        .filter(|v| {
            for (i, w) in waivers.iter().enumerate() {
                if w.rule == v.rule && w.path == v.path {
                    used[i] = true;
                    return false;
                }
            }
            true
        })
        .collect();
    for (i, w) in waivers.iter().enumerate() {
        if !used[i] {
            errors.push(format!(
                "vlint.toml:{}: stale waiver — {} on {} matches no violation; delete it",
                w.line, w.rule, w.path
            ));
        }
    }
    (surviving, errors)
}

// --------------------------------------------------------------------
// Driver
// --------------------------------------------------------------------

struct Options {
    root: PathBuf,
    waivers: Option<PathBuf>,
    design: Option<PathBuf>,
    proto_tests: Option<PathBuf>,
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> =
        entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn read(path: &Path) -> Result<String, String> {
    std::fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))
}

/// Run the whole pass.  Returns (files checked, surviving violations,
/// configuration errors).
fn run(opts: &Options) -> Result<(usize, Vec<Violation>, Vec<String>), String> {
    let src_root = opts.root.join("rust/src");
    let design_path =
        opts.design.clone().unwrap_or_else(|| opts.root.join("DESIGN.md"));
    let proto_tests_path = opts
        .proto_tests
        .clone()
        .unwrap_or_else(|| opts.root.join("rust/tests/wire_protocol.rs"));
    let waiver_path = opts.waivers.clone().unwrap_or_else(|| opts.root.join("vlint.toml"));

    let design = read(&design_path)?;
    let wire_tests = read(&proto_tests_path)?;
    let waivers = if waiver_path.exists() {
        parse_waivers(&read(&waiver_path)?)?
    } else {
        Vec::new()
    };

    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", src_root.display()));
    }

    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .map_err(|_| "path outside src root".to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let src = read(path)?;
        let toks = lex(&src);
        let mask = test_mask(&toks);
        violations.extend(check_tokens(&rel, &toks, &mask));
        if rel == "config/mod.rs" {
            violations.extend(check_config(&toks, &mask, &design));
        }
        if rel == "net/wire/proto.rs" {
            violations.extend(check_proto(&toks, &mask, &wire_tests));
        }
    }
    let (surviving, errors) = apply_waivers(violations, &waivers);
    Ok((files.len(), surviving, errors))
}

fn parse_args(args: &[String]) -> Result<Options, String> {
    let mut opts = Options {
        root: PathBuf::from("."),
        waivers: None,
        design: None,
        proto_tests: None,
    };
    let mut i = 0usize;
    while i < args.len() {
        let flag = args[i].as_str();
        let value = |i: usize| -> Result<PathBuf, String> {
            args.get(i + 1)
                .map(PathBuf::from)
                .ok_or_else(|| format!("{flag} needs a value"))
        };
        match flag {
            "--root" => opts.root = value(i)?,
            "--waivers" => opts.waivers = Some(value(i)?),
            "--design" => opts.design = Some(value(i)?),
            "--proto-tests" => opts.proto_tests = Some(value(i)?),
            other => return Err(format!("unknown flag '{other}' (see the crate docs)")),
        }
        i += 2;
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("vlint: {e}");
            return ExitCode::from(2);
        }
    };
    match run(&opts) {
        Ok((nfiles, violations, errors)) => {
            for v in &violations {
                println!("{v}");
            }
            for e in &errors {
                println!("{e}");
            }
            if violations.is_empty() && errors.is_empty() {
                println!("vlint: {nfiles} files clean");
                ExitCode::SUCCESS
            } else {
                println!(
                    "vlint: {} violation(s), {} waiver error(s) across {nfiles} files",
                    violations.len(),
                    errors.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("vlint: {e}");
            ExitCode::from(2)
        }
    }
}

// --------------------------------------------------------------------
// Fixture tests: one violating + one clean snippet per rule, waiver
// resolution, and staleness.
// --------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> Vec<Violation> {
        let toks = lex(src);
        let mask = test_mask(&toks);
        check_tokens(rel, &toks, &mask)
    }

    fn rules(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    // ---------------- lexer ----------------

    #[test]
    fn lexer_skips_comments_and_strings() {
        let toks = lex(concat!(
            "// unwrap in a comment\n",
            "/* panic! in /* nested */ block */\n",
            "let s = \"call .unwrap() here\";\n",
            "let r = r#\"Mutex::new\"#;\n",
        ));
        assert!(!toks.iter().any(|t| t.ident() == Some("unwrap")));
        assert!(!toks.iter().any(|t| t.ident() == Some("Mutex")));
        // but the string CONTENTS are retained for the rules that need them
        assert!(toks.iter().any(|t| matches!(&t.tok, Tok::Str(s) if s.contains("Mutex"))));
    }

    #[test]
    fn lexer_keeps_tuple_field_unwrap_visible() {
        // `pair.0.unwrap()`: the `0.` must not swallow the method dot
        let toks = lex("let x = pair.0.unwrap();");
        let idx = toks.iter().position(|t| t.ident() == Some("unwrap")).unwrap();
        assert!(toks[idx - 1].is_punct(b'.'));
    }

    #[test]
    fn lexer_separates_lifetimes_from_chars() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert_eq!(toks.iter().filter(|t| t.tok == Tok::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|t| t.tok == Tok::Char).count(), 1);
    }

    #[test]
    fn lexer_tracks_lines() {
        let toks = lex("a\n\nb\n");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 3);
    }

    // ---------------- R1 ----------------

    #[test]
    fn r1_flags_the_panic_family_in_hot_paths() {
        let src = r#"
            fn f(x: Option<u32>) -> u32 {
                let v = x.unwrap();
                let w = compute().expect("boom");
                if v > w { panic!("no"); }
                unreachable!()
            }
        "#;
        let v = check("net/wire/gateway.rs", src);
        assert_eq!(rules(&v), vec!["R1", "R1", "R1", "R1"]);
    }

    #[test]
    fn r1_allows_recovery_combinators_and_test_code() {
        let src = r#"
            fn f(x: Option<u32>) -> u32 {
                std::panic::panic_any("test hook");
                x.unwrap_or_else(|| 7) + x.unwrap_or_default()
            }
            #[cfg(test)]
            mod tests {
                #[test]
                fn g() { None::<u32>.unwrap(); panic!("fine in tests"); }
            }
        "#;
        assert!(check("memory/fabric.rs", src).is_empty());
    }

    #[test]
    fn r1_ignores_files_outside_the_hot_dirs() {
        let v = check("coordinator/query.rs", "fn f() { x.unwrap(); }");
        assert!(v.is_empty());
    }

    // ---------------- R2 ----------------

    #[test]
    fn r2_flags_raw_locks_everywhere_but_sync() {
        let src = "use std::sync::Mutex;\nstatic L: RwLock<u8> = RwLock::new(0);";
        let v = check("coordinator/query.rs", src);
        assert_eq!(rules(&v), vec!["R2", "R2", "R2"]);
        assert!(check("util/sync.rs", src).is_empty(), "the sync layer itself is exempt");
    }

    #[test]
    fn r2_accepts_the_ordered_wrappers() {
        let src = "use crate::util::sync::{OrderedMutex, OrderedRwLock, OrderedCondvar};";
        assert!(check("server/mod.rs", src).is_empty());
    }

    #[test]
    fn r2_skips_cfg_test_items_but_not_cfg_not_test() {
        let test_only = "#[cfg(test)]\nmod tests { use std::sync::Mutex; }";
        assert!(check("api/cache.rs", test_only).is_empty());
        let prod = "#[cfg(not(test))]\nfn f() { let m = Mutex::new(0); }";
        assert_eq!(rules(&check("api/cache.rs", prod)), vec!["R2"]);
    }

    // ---------------- R3 ----------------

    const CONFIG_FIXTURE: &str = r#"
        const KNOWN_KEYS: &[&str] = &["a.x", "a.y"];
        fn load(d: &TomlDoc) {
            let _ = d.f64_or("a.x", 0.0);
            let _ = d.usize_or("a.y", 1);
        }
    "#;

    fn r3(src: &str, design: &str) -> Vec<Violation> {
        let toks = lex(src);
        let mask = test_mask(&toks);
        check_config(&toks, &mask, design)
    }

    #[test]
    fn r3_clean_when_reads_known_and_design_agree() {
        assert!(r3(CONFIG_FIXTURE, "table: `a.x` and `a.y`").is_empty());
    }

    #[test]
    fn r3_flags_reads_missing_from_known_keys() {
        let src = r#"
            const KNOWN_KEYS: &[&str] = &["a.x"];
            fn load(d: &TomlDoc) {
                let _ = d.f64_or("a.x", 0.0);
                let _ = d.bool_or("a.ghost", false);
            }
        "#;
        let v = r3(src, "`a.x`");
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("a.ghost"));
    }

    #[test]
    fn r3_flags_undocumented_and_unread_keys() {
        let src = r#"
            const KNOWN_KEYS: &[&str] = &["a.x", "a.stale"];
            fn load(d: &TomlDoc) { let _ = d.f64_or("a.x", 0.0); }
        "#;
        let v = r3(src, "`a.x` only");
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("not documented")));
        assert!(v.iter().any(|x| x.msg.contains("never read")));
    }

    // ---------------- R4 ----------------

    const PROTO_FIXTURE: &str = r#"
        fn to_json(&self) -> Json {
            let m = tagged("hello");
            let e = tagged("error");
        }
    "#;

    fn r4(proto: &str, tests: &str) -> Vec<Violation> {
        let toks = lex(proto);
        let mask = test_mask(&toks);
        check_proto(&toks, &mask, tests)
    }

    #[test]
    fn r4_requires_a_vector_per_tag() {
        let tests = r##"send(br#"{"type":"hello"}"#);"##;
        let v = r4(PROTO_FIXTURE, tests);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("'error'"));
    }

    #[test]
    fn r4_clean_when_every_tag_is_covered() {
        let tests = r##"
            send(br#"{"type":"hello"}"#);
            send(br#"{"type":"error","error":{}}"#);
        "##;
        assert!(r4(PROTO_FIXTURE, tests).is_empty());
    }

    // ---------------- R5 ----------------

    #[test]
    fn r5_flags_prints_and_exits_outside_cli() {
        let src = "fn f() { println!(\"hi\"); std::process::exit(1); }";
        assert_eq!(rules(&check("server/mod.rs", src)), vec!["R5", "R5"]);
        assert!(check("cli/mod.rs", src).is_empty(), "cli/ may print and exit");
    }

    #[test]
    fn r5_allows_eprintln_diagnostics() {
        assert!(check("eval/runner.rs", "fn f() { eprintln!(\"warn\"); }").is_empty());
    }

    // ---------------- waivers ----------------

    const WAIVER_FIXTURE: &str = r#"
        # justified waivers
        [[waiver]]
        rule = "R5"
        path = "rust/src/util/bench.rs"
        reason = "bench harness prints paper tables by design"
    "#;

    fn fake(rule: &'static str, path: &str) -> Violation {
        Violation { rule, path: path.to_string(), line: 1, msg: "x".to_string() }
    }

    #[test]
    fn waivers_suppress_matching_violations() {
        let ws = parse_waivers(WAIVER_FIXTURE).unwrap();
        let (left, errors) = apply_waivers(
            vec![fake("R5", "rust/src/util/bench.rs"), fake("R5", "rust/src/eval/runner.rs")],
            &ws,
        );
        assert_eq!(left.len(), 1, "only the un-waived violation survives");
        assert_eq!(left[0].path, "rust/src/eval/runner.rs");
        assert!(errors.is_empty());
    }

    #[test]
    fn stale_waivers_are_errors() {
        let ws = parse_waivers(WAIVER_FIXTURE).unwrap();
        let (left, errors) = apply_waivers(Vec::new(), &ws);
        assert!(left.is_empty());
        assert_eq!(errors.len(), 1);
        assert!(errors[0].contains("stale waiver"));
    }

    #[test]
    fn hot_path_lock_and_panic_waivers_are_rejected() {
        for rule in ["R1", "R2"] {
            let toml = format!(
                "[[waiver]]\nrule = \"{rule}\"\npath = \"rust/src/net/wire/gateway.rs\"\n\
                 reason = \"tempting but forbidden\"\n"
            );
            let ws = parse_waivers(&toml).unwrap();
            let (_, errors) =
                apply_waivers(vec![fake("R1", "rust/src/net/wire/gateway.rs")], &ws);
            assert!(
                errors.iter().any(|e| e.contains("not waivable")),
                "{rule} hot-path waiver must be rejected: {errors:?}"
            );
        }
    }

    #[test]
    fn waivers_without_a_reason_fail_to_parse() {
        let toml = "[[waiver]]\nrule = \"R5\"\npath = \"rust/src/main.rs\"\nreason = \"  \"\n";
        let err = parse_waivers(toml).unwrap_err();
        assert!(err.contains("justification"), "{err}");
    }

    #[test]
    fn unknown_waiver_fields_fail_to_parse() {
        let toml = "[[waiver]]\nrule = \"R5\"\npath = \"x\"\nseverity = \"low\"\n";
        assert!(parse_waivers(toml).is_err());
    }
}
